"""Device-resident delta buckets: the O(delta) post-mutation contract.

Pins the three properties the ROADMAP items demanded:

  * post-mutation device refresh moves O(delta) rows — transfer counters
    and delta-bucket shapes are IDENTICAL for the same mutation sequence on
    a 1x and a >=4x base (nothing scales with the base),
  * buffers are reused inside a power-of-two bucket: growing the delta
    without crossing a bucket boundary reallocates nothing, and the base
    arrays keep their identity across versions (no [base | delta]
    re-concatenation anywhere),
  * compact() assembled on device (merge-path kernel + stream compaction)
    is bit-identical to the host searchsorted merge, across modes,
    tombstones included.
"""
import numpy as np
import pytest

from repro.core.delta import compact_view
from repro.core.engine import KnowledgeBase
from repro.core.query import Pattern
from repro.core.tbox import Ontology
from repro.rdf.generator import generate_random_abox


def _onto() -> Ontology:
    concepts = [f"C{i}" for i in range(7)]
    props = [f"p{i}" for i in range(4)]
    return Ontology(
        concepts=concepts, properties=props,
        subclass=[(concepts[i], concepts[max(0, i - 2)]) for i in range(1, 7)],
        subprop=[(props[1], props[0])],
        domain={props[0]: [concepts[1]]},
        range_={props[3]: [concepts[2]]},
    )


def _kb(onto, scale: int, seed: int = 0) -> KnowledgeBase:
    raw = generate_random_abox(
        onto, n_instances=40 * scale, n_type_triples=60 * scale,
        n_prop_triples=50 * scale, seed=seed)
    return KnowledgeBase.build(raw)


def _disjoint_delta(onto, seed: int, n_inst=30, n_type=20, n_prop=15):
    """A delta whose instance terms are DISJOINT from every base's.

    ``generate_random_abox`` draws instances from one shared fingerprint
    space, so its deltas alias base instances; the O(delta) pins need a
    pure-growth delta whose delete re-derivation frontier cannot touch the
    base.
    """
    from repro.core.tbox import RDF_TYPE
    from repro.rdf.generator import RawDataset
    from repro.utils.hashing import fingerprint_string, mix64

    rng = np.random.default_rng(seed)
    inst = mix64(np.int64(777), np.arange(n_inst) + 1_000_000, 0, 0)
    cfps = np.array([fingerprint_string(c) for c in onto.concepts])
    pfps = np.array([fingerprint_string(p) for p in onto.properties])
    s = np.concatenate([inst[rng.integers(0, n_inst, n_type)],
                        inst[rng.integers(0, n_inst, n_prop)]])
    p = np.concatenate([np.full(n_type, fingerprint_string(RDF_TYPE)),
                        pfps[rng.integers(0, len(pfps), n_prop)]])
    o = np.concatenate([cfps[rng.integers(0, len(cfps), n_type)],
                        inst[rng.integers(0, n_inst, n_prop)]])
    return RawDataset(s=s, p=p, o=o, onto=onto)


def _mutate(K, onto, seed: int, disjoint: bool = False):
    """One fixed-size insert + one fixed-size delete (same on every base)."""
    extra = (_disjoint_delta(onto, seed) if disjoint else
             generate_random_abox(onto, n_instances=30, n_type_triples=20,
                                  n_prop_triples=15, seed=seed))
    K.insert(extra, auto_compact=False)
    K.delete((extra.s[:5], extra.p[:5], extra.o[:5]), auto_compact=False)
    return extra


QUERY = [Pattern("?x", "rdf:type", "C1")]


@pytest.mark.parametrize("mode", ["litemat", "full", "rewrite"])
def test_warmup_transfer_independent_of_base_size(mode):
    """Same delta on a 1x and a 4x base -> identical device-transfer stats.

    The update-slice extent, delta-bucket shapes, and every upload counter
    must depend only on the delta; only the one-time base-alive upload of
    the first delete (and kill scatters) may differ in *content*, never in
    delta terms.  Pinned for ALL THREE serving modes: the lazily derived
    lite/full delta materializations and the rewrite-mode raw log all land
    in O(delta) buckets whose refresh never scales with the base.
    """
    onto = _onto()
    snaps = {}
    for scale in (1, 4):
        K = _kb(onto, scale)
        K.answers(QUERY, mode=mode)  # build base state pre-mutation
        cache = K.dev_cache(mode)
        before = dict(cache.stats)
        _mutate(K, onto, seed=99, disjoint=True)
        K.answers(QUERY, mode=mode)  # first post-mutation query: syncs buffers
        after = dict(cache.stats)
        delta_stats = {k: after[k] - before[k] for k in after}
        shapes = {k: cache.buffer_shapes(k)
                  for k in ("scan", "pos") if cache.buffer_shapes(k)}
        snaps[scale] = (delta_stats, shapes)

    stats1, shapes1 = snaps[1]
    stats4, shapes4 = snaps[4]
    # delta-sized transfers: identical regardless of base size
    for key in ("upload_delta_rows", "upload_alive_rows", "delta_allocs"):
        assert stats1[key] == stats4[key], (key, stats1, stats4)
    # the delta bucket shape (= the dynamic-update-slice extent) matches too
    assert shapes1 == shapes4
    # and nothing fell back to a full [base | delta] rebuild
    assert stats1["stale_view_builds"] == stats4["stale_view_builds"] == 0


def test_bucket_growth_reuses_buffers():
    """Delta growth inside a pow2 bucket reallocates nothing; the base
    arrays keep their identity across every version."""
    onto = _onto()
    K = _kb(onto, 1)
    K.answers(QUERY)
    cache = K.dev_cache("litemat")
    base0 = K.view("litemat").dev("pos").base

    def tiny(seed, n):
        return generate_random_abox(onto, n_instances=5, n_type_triples=n,
                                    n_prop_triples=0, seed=seed)

    K.insert(tiny(1, 3), auto_compact=False)
    K.answers(QUERY)
    allocs0 = cache.stats["delta_allocs"]
    shape0, cap0 = cache.buffer_shapes("pos")

    # grow WITHIN the bucket: no new allocation, same shapes
    K.insert(tiny(2, 2), auto_compact=False)
    K.answers(QUERY)
    assert cache.stats["delta_allocs"] == allocs0
    assert cache.buffer_shapes("pos") == (shape0, cap0)

    # cross the pow2 boundary: exactly the delta bucket reallocates
    lite_delta = K.delta.log("litemat").n
    grow = generate_random_abox(onto, n_instances=40,
                                n_type_triples=4 * cap0,
                                n_prop_triples=0, seed=3)
    K.insert(grow, auto_compact=False)
    K.answers(QUERY)
    assert K.delta.log("litemat").n > cap0 >= lite_delta
    assert cache.stats["delta_allocs"] > allocs0
    (shape1, cap1) = cache.buffer_shapes("pos")
    assert cap1 > cap0 and shape1[0] == cap1

    # the base device array was NEVER copied or re-concatenated
    assert K.view("litemat").dev("pos").base is base0


def _donation_reuses_buffers() -> bool:
    """Probe whether this backend honors jit buffer donation."""
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda m: m.at[0].set(False), donate_argnums=(0,))
    x = jnp.ones(128, dtype=bool)
    ptr = x.unsafe_buffer_pointer()
    return f(x).unsafe_buffer_pointer() == ptr


def test_delete_kill_scatters_donate_alive_buffer_in_place():
    """PINNED: a delete batch flips bits in the SAME device buffer.

    The kill scatter donates the resident base-alive mask, so XLA updates
    it in place — no O(base) copy-then-scatter per delete batch, no
    base-sized transfer, and no shared-mask privatization after the first:
    the buffer pointer survives the batch.
    """
    if not _donation_reuses_buffers():
        pytest.skip("backend does not honor buffer donation")
    onto = _onto()
    K = _kb(onto, 2)
    raw_extra = _mutate(K, onto, seed=7)  # tombstone state exists up front
    K.answers(QUERY)  # resident buffers own a private base-alive mask
    cache = K.dev_cache("litemat")
    # drain in-flight async computations first: a still-referenced input
    # makes XLA copy instead of reusing the donated buffer
    base_alive = K.view("litemat").dev("pos").base_alive
    base_alive.block_until_ready()
    ptr0 = base_alive.unsafe_buffer_pointer()
    del base_alive
    before = dict(cache.stats)
    K.delete((raw_extra.s[5:9], raw_extra.p[5:9], raw_extra.o[5:9]),
             auto_compact=False)
    K.answers(QUERY)
    after = dict(cache.stats)
    # same buffer, updated in place by the donated scatter
    assert (K.view("litemat").dev("pos").base_alive.unsafe_buffer_pointer()
            == ptr0)
    assert after["kill_scatter_rows"] > before["kill_scatter_rows"]
    # and the batch shipped/copied nothing base-sized
    assert after["upload_base_alive_rows"] == before["upload_base_alive_rows"]
    assert after["alive_privatize_rows"] == before["alive_privatize_rows"]


def test_delete_applies_kill_scatters_not_mask_uploads():
    """Deletes after the first reach the device as point scatters."""
    onto = _onto()
    K = _kb(onto, 2)
    raw_extra = _mutate(K, onto, seed=7)  # creates tombstone state + buffers
    K.answers(QUERY)
    cache = K.dev_cache("litemat")
    before = dict(cache.stats)
    K.delete((raw_extra.s[5:9], raw_extra.p[5:9], raw_extra.o[5:9]),
             auto_compact=False)
    K.answers(QUERY)
    after = dict(cache.stats)
    assert after["kill_scatter_rows"] > before["kill_scatter_rows"]
    # no O(base) mask re-upload once the state exists
    assert after["upload_base_alive_rows"] == before["upload_base_alive_rows"]


@pytest.mark.parametrize("mode", ["rewrite", "litemat", "full"])
def test_compact_device_bit_identical_to_host(mode):
    """PINNED: device-side compaction == host merge, byte for byte."""
    onto = _onto()
    K = _kb(onto, 2)
    _mutate(K, onto, seed=21)
    _mutate(K, onto, seed=22)
    v = K.view(mode)
    host_rows, host_idx = compact_view(v, device=False)
    dev_rows, dev_idx = compact_view(v, device=True)
    np.testing.assert_array_equal(np.asarray(host_rows), np.asarray(dev_rows))
    np.testing.assert_array_equal(host_idx._h, dev_idx._h)


def test_compact_device_end_to_end_preserves_answers():
    """KnowledgeBase.compact(device=True) leaves every mode's answers as-is."""
    onto = _onto()
    K = _kb(onto, 1)
    _mutate(K, onto, seed=31)
    before = {m: K.answers(QUERY, mode=m)
              for m in ("litemat", "full", "rewrite")}
    st = K.compact(device=True)
    assert st["compacted"]
    after = {m: K.answers(QUERY, mode=m)
             for m in ("litemat", "full", "rewrite")}
    assert before == after
    # post-compaction, executables run against the NEW device base arrays
    assert K.view("litemat").dev("pos").base.shape[0] == st["litemat"]


def test_stale_view_snapshot_stays_consistent():
    """A view held across later mutations serves its own snapshot."""
    onto = _onto()
    K = _kb(onto, 1)
    K.insert(generate_random_abox(onto, n_instances=10, n_type_triples=8,
                                  n_prop_triples=4, seed=41),
             auto_compact=False)
    old = K.view("litemat")
    n_old = old.n_live
    old_rows = old.dev("scan")  # sync the cache at this version
    K.insert(generate_random_abox(onto, n_instances=10, n_type_triples=8,
                                  n_prop_triples=4, seed=42),
             auto_compact=False)
    K.view("litemat").dev("scan")  # cache moves to the new version
    again = old.dev("scan")  # stale view: one-off build, same content
    assert old.n_live == n_old
    np.testing.assert_array_equal(np.asarray(old_rows.delta)[:old.delta_n],
                                  np.asarray(again.delta)[:old.delta_n])
    assert K.dev_cache("litemat").stats["stale_view_builds"] >= 1


def test_pre_compaction_view_never_rewinds_cache():
    """A snapshot from BEFORE a compaction must not thrash the cache.

    Alternating queries between a held pre-compaction view and the live KB
    must serve the old view as one-off builds — rewinding the resident
    state to the dead base would degrade every live query to an O(base)
    rebuild.
    """
    onto = _onto()
    K = _kb(onto, 1)
    K.insert(_disjoint_delta(onto, seed=51), auto_compact=False)
    old = K.view("litemat")
    old.dev("pos")
    K.compact()
    K.answers(QUERY)  # resident state now belongs to the NEW base
    cache = K.dev_cache("litemat")
    rebuilds = cache.stats["base_rebuilds"]
    live = K.view("litemat").dev("pos").base
    for _ in range(3):  # alternate: held snapshot vs live store
        old.dev("pos")
        assert K.view("litemat").dev("pos").base is live
    assert cache.stats["base_rebuilds"] == rebuilds  # never rewound
    assert cache.stats["stale_view_builds"] >= 3
