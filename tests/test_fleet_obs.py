"""Fleet telemetry: mergeable sketches, resource ledger, SLO control loop.

The PR-10 acceptance bar, as tests:

  * histogram-sketch states merge associatively and order-independently,
    and a merged sketch's percentiles equal the pooled stream's within
    one log bucket (here: exactly — bucket-wise addition IS the pooled
    sketch);
  * per-process mergeable snapshots round-trip through export validation,
    and the aggregator rejects mixed-schema / duplicate-process inputs
    with clear errors instead of skewing fleet percentiles;
  * the resource ledger's ``hbm_bytes`` / ``bytes_per_triple`` gauges
    agree with independently computed buffer sizes on a known store,
    dedupe shared buffers, and zero out when an owner is dropped;
  * the SLO monitor drives the serving runtime's admission bound DOWN
    under injected overload and back up on recovery, with every
    transition landing as a schema-valid trace — and a fault injected at
    the control-plane apply site leaves the data plane's knobs untouched;
  * the capacity-retry sites record ``join/capacity_retry`` counters and
    doubling-depth histograms, and EXPLAIN surfaces observed hot-key
    skew.
"""
import gc
import json
import math

import numpy as np
import pytest

from repro.core.engine import KnowledgeBase, PAPER_QUERIES
from repro.obs.aggregate import (AggregationError, aggregate,
                                 check_compatible)
from repro.obs.export import (export_mergeable_metrics,
                              validate_metrics_snapshot)
from repro.obs.ledger import ResourceLedger
from repro.obs.metrics import (MetricsRegistry, REGISTRY, _GROWTH_LOG,
                               merge_states, summarize_state)
from repro.obs.slo import SLO, SLOMonitor, TelemetryRollup, _spec
from repro.obs.trace import Tracer
from repro.serving.runtime import ServingRuntime
from repro.testing import faults

Q1, Q4 = PAPER_QUERIES["Q1"], PAPER_QUERIES["Q4"]


@pytest.fixture(autouse=True)
def _no_leaked_injector():
    yield
    faults.uninstall()


# -- mergeable histogram sketches ---------------------------------------------

def _hist_with(reg, values, **labels):
    h = reg.histogram("t/lat", **labels)
    for v in values:
        h.observe(v)
    return h


def test_merge_states_associative_and_order_independent():
    rng = np.random.default_rng(3)
    regs = [MetricsRegistry() for _ in range(3)]
    streams = [rng.lognormal(-3, 1, 500), rng.lognormal(-2, 0.5, 300),
               rng.lognormal(-4, 2, 700)]
    states = [_hist_with(reg, s).state()
              for reg, s in zip(regs, streams)]
    a, b, c = states
    left = merge_states(merge_states(a, b), c)
    right = merge_states(a, merge_states(b, c))
    shuffled = merge_states(c, a, b)
    for other in (right, shuffled):
        # counts/buckets/min/max are integers or copied floats: exact.
        # "sum" reassociates float additions, so approx only.
        assert {k: v for k, v in left.items() if k != "sum"} \
            == {k: v for k, v in other.items() if k != "sum"}
        assert left["sum"] == pytest.approx(other["sum"])
    assert left["count"] == sum(len(s) for s in streams)
    assert left["sum"] == pytest.approx(sum(s.sum() for s in streams))
    assert left["min"] == pytest.approx(min(s.min() for s in streams))
    assert left["max"] == pytest.approx(max(s.max() for s in streams))


def test_merged_percentiles_match_pooled_stream_within_one_bucket():
    rng = np.random.default_rng(11)
    streams = [rng.lognormal(-3, 1, 400) for _ in range(4)]
    pooled_reg = MetricsRegistry()
    pooled = _hist_with(pooled_reg, np.concatenate(streams))
    merged = merge_states(*[
        _hist_with(MetricsRegistry(), s).state() for s in streams])
    ms = summarize_state(merged)
    one_bucket = math.exp(_GROWTH_LOG)
    for q in (50, 99):
        p_pool = pooled.percentile(q)
        p_merge = ms[f"p{q}"]
        assert p_merge / p_pool <= one_bucket + 1e-9
        assert p_pool / p_merge <= one_bucket + 1e-9
    # bucket-wise addition IS the pooled sketch: exact equality too
    assert merged["buckets"] == pooled.state()["buckets"]


def test_mergeable_snapshot_roundtrip_and_validation(tmp_path):
    reg = MetricsRegistry()
    reg.counter("t/reqs", status="ok").inc(5)
    reg.gauge("t/depth").set(3.5)
    _hist_with(reg, [0.01, 0.02, 0.4], mode="x")
    path = tmp_path / "snap.json"
    export_mergeable_metrics(reg, str(path), process="7")
    snap = json.loads(path.read_text())
    assert snap["schema"] == "repro.metrics.snapshot/1"
    assert snap["process"] == "7"
    assert validate_metrics_snapshot(snap) == []
    # corrupt a bucket count: the validator names the inconsistency
    snap["histograms"][0]["buckets"][
        next(iter(snap["histograms"][0]["buckets"]))] += 1
    errs = validate_metrics_snapshot(snap)
    assert errs and "bucket counts sum" in errs[0]
    # unknown schema versions fail loudly, never silently skew a merge
    errs = validate_metrics_snapshot({"schema": "repro.metrics/99"})
    assert errs and "unknown metrics snapshot schema" in errs[0]


def test_aggregate_sums_counters_and_rejects_bad_inputs():
    snaps = []
    for proc in ("0", "1"):
        reg = MetricsRegistry()
        reg.counter("t/reqs", status="ok").inc(3)
        reg.gauge("t/depth").set(float(proc))
        _hist_with(reg, [0.01, 0.1])
        snaps.append(reg.mergeable_snapshot(process=proc))
    fleet = aggregate(snaps)
    assert fleet["schema"] == "repro.metrics.fleet/1"
    assert fleet["processes"] == ["0", "1"]
    assert validate_metrics_snapshot(fleet) == []
    [ctr] = [e for e in fleet["counters"] if e["name"] == "t/reqs"]
    assert ctr["value"] == 6  # counters SUM across processes
    # gauges stay per-process (labelled), never averaged
    depths = {e["labels"]["process"]: e["value"]
              for e in fleet["gauges"] if e["name"] == "t/depth"}
    assert depths == {"0": 0.0, "1": 1.0}
    [h] = [e for e in fleet["histograms"] if e["name"] == "t/lat"]
    assert h["count"] == 4 and "summary" in h
    # duplicate process names would collide on every gauge: rejected
    with pytest.raises(AggregationError, match="claim process"):
        check_compatible([snaps[0], snaps[0]])
    # mixed schema versions: rejected with the offending value named
    bad = dict(snaps[1], schema="repro.metrics.snapshot/0")
    with pytest.raises(AggregationError, match="snapshot/0"):
        aggregate([snaps[0], bad])
    # mixed bucket-growth constants cannot merge bucket-wise
    bad = dict(snaps[1], growth_log=_GROWTH_LOG * 2)
    with pytest.raises(AggregationError, match="growth_log"):
        aggregate([snaps[0], bad])


# -- resource ledger ----------------------------------------------------------

class _Owner:
    """Minimal device_buffers() provider over plain numpy arrays."""

    def __init__(self, arrays, triples=0):
        self.arrays = arrays
        self.triples = triples

    def device_buffers(self):
        return [(comp, id(a), a.nbytes) for comp, a in self.arrays]

    def n_live_triples(self):
        return self.triples


def test_ledger_accounts_dedupes_and_zeroes():
    reg = MetricsRegistry()
    led = ResourceLedger(registry=reg)
    shared = np.zeros(1024, np.int32)  # 4096 B, owned by BOTH owners
    a = _Owner([("base", np.zeros(256, np.int32)), ("base", shared)],
               triples=100)
    b = _Owner([("delta", shared)], triples=50)
    led.track("0", a)
    led.track("1", b)
    s = led.sample()
    # shared buffer counts ONCE, attributed to the first-registered owner
    assert s["shards"]["0"]["components"]["base"] == 1024 + 4096
    assert s["shards"]["1"].get("components") == {}
    assert s["total_bytes"] == 1024 + 4096
    assert s["total_triples"] == 150
    assert reg.gauge_value("hbm_bytes", shard="0", component="base") == 5120
    assert reg.gauge_value("store/bytes_per_triple") == pytest.approx(
        5120 / 150)
    # dropping an owner zeroes its gauges on the next sample — a dead
    # store must not leave stale byte counts behind
    del a
    gc.collect()
    s2 = led.sample()
    assert "0" not in s2["shards"]
    assert reg.gauge_value("hbm_bytes", shard="0", component="base") == 0
    # ...and the survivor now owns the shared buffer
    assert s2["shards"]["1"]["components"]["delta"] == 4096


def test_ledger_matches_independent_buffer_sizes(lubm_kb):
    K, raw = lubm_kb
    reg = MetricsRegistry()
    led = ResourceLedger(registry=reg)
    led.track("0", K)
    K.query(Q1)  # materialize indexes + device caches
    s = led.sample()
    rec = s["shards"]["0"]
    # independent lower bound: the three raw store arrays must be counted
    floor = K.kb.spo.nbytes + K.lite_spo.nbytes + K.full_spo.nbytes
    assert rec["components"]["base"] >= floor
    # live triples agree with the store's own row count
    assert rec["triples"] == K.n_live_triples()
    assert rec["triples"] == np.asarray(K.store_rows("litemat")).shape[0]
    assert s["bytes_per_triple"] == pytest.approx(
        s["total_bytes"] / s["total_triples"])
    # sampling is read-only: a second sample reports identical bytes
    assert led.sample()["total_bytes"] == s["total_bytes"]


def test_sharded_ledger_reports_every_shard(lubm_kb):
    from repro.core.shard import ShardedKB

    _, raw = lubm_kb
    S = ShardedKB.build(raw, n_shards=4)
    reg = MetricsRegistry()
    led = ResourceLedger(registry=reg)
    for i, K in enumerate(S.shards):
        led.track(str(i), K)
    led.track("stack", S)
    S.query(Q4)
    s = led.sample()
    for i in range(4):
        rec = s["shards"][str(i)]
        assert rec["total"] > 0 and rec["triples"] > 0
        assert reg.gauge_value("hbm_bytes", shard=str(i),
                               component="base") > 0
    # per-shard triples sum to the whole store's litemat rows
    total = sum(s["shards"][str(i)]["triples"] for i in range(4))
    assert total == np.asarray(S.store_rows("litemat")).shape[0]


# -- SLO monitor + admission control loop -------------------------------------

def _mk_points(pairs, den_spec, num_spec):
    """Timeline of points from cumulative (den, num) counter pairs."""
    return [{"t": float(i), "counters": {den_spec: d, num_spec: n},
             "hists": {}, "rates": {}} for i, (d, n) in enumerate(pairs)]


def test_monitor_burn_rates_and_state_machine():
    reg = MetricsRegistry()
    den, num = _spec("t/submitted"), _spec("t/outcomes", status="deadline")
    slo = SLO(name="miss", objective=0.01, num=num, den=den)
    mon = SLOMonitor([slo], fast_window=2, slow_window=4, min_events=4,
                     registry=reg)
    seen = []
    mon.on_transition(lambda st, detail: seen.append(st))
    # healthy: 100 events/tick, zero bad
    tl = _mk_points([(0, 0), (100, 0), (200, 0), (300, 0)], den, num)
    assert mon.observe(tl) == "ok" and seen == []
    # sustained 50% miss rate = 50x budget: page
    tl = _mk_points([(0, 0), (100, 50), (200, 100), (300, 150),
                     (400, 200)], den, num)
    assert mon.observe(tl) == "page" and seen == ["page"]
    assert reg.gauge_value("slo/burn_rate", slo="miss",
                           window="fast") >= 2.0
    # recovery: fast window clean, slow window still dirty -> min() clears
    tl = _mk_points([(0, 100), (100, 100), (200, 100), (300, 100),
                     (400, 100)], den, num)
    assert mon.observe(tl) == "ok" and seen == ["page", "ok"]
    # too few events: no signal, no flapping
    tl = _mk_points([(0, 0), (2, 2)], den, num)
    assert mon.observe(tl) == "ok"


@pytest.fixture()
def slo_rt(lubm_kb):
    K, _ = lubm_kb
    tracer = Tracer()
    rt = ServingRuntime(K, max_queue=32, tracer=tracer)
    # interval_s is huge: the tests drive tick() by hand so window
    # contents are deterministic (a background tick between bursts would
    # observe an empty fast window and recover early)
    mon = rt.enable_slo_control(interval_s=60.0, fast_window=2,
                                slow_window=4, min_events=4)
    with rt:
        rt.serve(Q4)  # compile warmup before any deadline-bounded traffic
        yield rt, mon, tracer


def test_slo_loop_tightens_admission_and_recovers(slo_rt):
    rt, mon, tracer = slo_rt
    tick = rt._slo_rollup.tick
    for _ in range(12):
        assert rt.serve(Q4).ok
    tick(); tick()
    assert mon.state == "ok"
    b0, w0 = rt.admission_bound, rt.batch_window_s
    # injected overload: every execute faults, deadlines pile up, and the
    # monitor pages -> admission bound drops, batch window widens
    with faults.inject() as inj:
        inj.arm("serving.execute", times=0)
        for _ in range(4):
            for _ in range(10):
                rt.serve(Q4, deadline_s=0.01)
            tick()
    assert mon.state == "page"
    assert rt.admission_bound < b0
    assert rt.batch_window_s > w0
    assert rt.metrics.gauge_value("serving/admission_bound") == \
        rt.admission_bound
    # recovery: healthy traffic drains the windows, knobs restore
    for _ in range(6):
        for _ in range(8):
            assert rt.serve(Q4).ok
        tick()
    assert mon.state == "ok"
    assert rt.admission_bound == b0 and rt.batch_window_s == w0
    # every transition landed as its own schema-valid single-span trace
    from repro.obs.export import validate_trace

    trans = [t for t in tracer.finished_traces()
             if t.root.name == "slo_transition"]
    assert len(trans) >= 2
    states = [t.root.attrs["to"] for t in trans]
    assert "page" in states and states[-1] == "ok"
    for t in trans:
        assert validate_trace(t.to_dict()) == []


def test_slo_apply_fault_leaves_data_plane_knobs(slo_rt):
    rt, mon, _ = slo_rt
    tick = rt._slo_rollup.tick
    for _ in range(12):
        rt.serve(Q4)
    tick(); tick()
    b0 = rt.admission_bound
    # the CONTROL plane faults at apply time: the monitor pages but the
    # runtime keeps its previous knobs (serving never degrades because
    # telemetry glue broke)
    with faults.inject() as inj:
        inj.arm("slo.apply", times=0)
        inj.arm("serving.execute", times=0)
        for _ in range(4):
            for _ in range(10):
                rt.serve(Q4, deadline_s=0.01)
            tick()
        assert mon.state == "page"
        assert rt.admission_bound == b0  # apply faulted: knobs unchanged
        assert rt.metrics.counter_value("slo/apply_faults") >= 1
    # with the fault gone, the next transition applies normally
    for _ in range(6):
        for _ in range(8):
            rt.serve(Q4)
        tick()
    assert mon.state == "ok" and rt.admission_bound == b0


def test_rollup_rates_are_first_class_series():
    reg = MetricsRegistry()
    roll = TelemetryRollup(reg, maxlen=8)
    reg.counter("serving/submitted").inc(10)
    roll.tick()
    reg.counter("serving/submitted").inc(30)
    roll.tick()
    series = roll.rate_series("serving/submitted")
    assert len(series) == 1 and series[0][1] > 0
    assert reg.gauge_value("rate/serving/submitted") == series[0][1]
    for _ in range(20):  # timeline stays bounded
        roll.tick()
    assert len(roll.timeline) == 8


# -- capacity-retry instrumentation + hot-key skew ----------------------------

def test_forced_overflow_records_capacity_retry_metrics(lubm_kb):
    K, _ = lubm_kb
    eng = K.engine("litemat")
    planned = list(eng._plan(Q1, None))
    # shrink every capacity below the planner's estimate: the first
    # dispatch overflows and the doubling ladder must climb back
    planned[2] = [256] * len(planned[2])
    planned[3] = 256
    assert max(planned[7]) > 256, "query too small to force an overflow"
    before = sum(REGISTRY.values("join/capacity_retry").values())
    rows, _ = eng._run_planned(tuple(planned), max_retries=10)
    retries = sum(REGISTRY.values("join/capacity_retry").values())
    assert rows.shape[0] > 0
    assert retries > before
    # depth histogram landed for the query site
    depth = [(labels, h) for (name, labels), h in
             REGISTRY._histograms.items() if name == "join/capacity_depth"]
    assert any(dict(labels).get("site") == "query" and h.count > 0
               for labels, h in depth)


def test_explain_surfaces_hot_key_skew(lubm_kb):
    K, _ = lubm_kb
    ex = K.engine("litemat").explain(Q4)
    assert "hot_keys" in ex
    assert ex["hot_keys"], "multi-pattern query must report join-var skew"
    for var, rec in ex["hot_keys"].items():
        assert rec["max_rows_per_key"] >= 1
        assert rec["skew"] >= 1.0 - 1e-9
        assert rec["max_rows_per_key"] <= ex["n_result_rows"]
