"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property tests.

Kernels run in interpret mode on CPU (the kernel bodies execute verbatim);
on a real TPU the same wrappers compile the Mosaic path.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [1, 100, 4096, 5000])
@pytest.mark.parametrize("block", [1024, 4096])
def test_interval_filter_sweep(n, block, rng):
    p = jnp.asarray(rng.integers(0, 1000, n), jnp.int32)
    o = jnp.asarray(rng.integers(0, 1 << 20, n), jnp.int32)
    params = jnp.asarray([100, 300, 0, 1 << 19], jnp.int32)
    got = ops.interval_filter(p, o, params, block=block)
    want = ref.ref_interval_filter(None, p, o, 100, 300, 0, 1 << 19, 0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("G,K", [(1, 4), (37, 16), (130, 8), (64, 33)])
def test_msc_select_sweep(G, K, rng):
    conc = rng.integers(-1, 500, (G, K)).astype(np.int32)
    bounds = conc + rng.integers(1, 64, (G, K)).astype(np.int32)
    got = ops.msc_select(jnp.asarray(conc), jnp.asarray(bounds))
    want = ref.ref_msc_select(jnp.asarray(conc), jnp.asarray(bounds))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(1, 12), st.integers(2, 24), st.integers(0, 2**31 - 2))
@settings(max_examples=25, deadline=None)
def test_msc_select_property(g, k, seed):
    rng = np.random.default_rng(seed)
    conc = rng.integers(-1, 100, (g, k)).astype(np.int32)
    bounds = conc + rng.integers(1, 32, (g, k)).astype(np.int32)
    got = np.asarray(ops.msc_select(jnp.asarray(conc), jnp.asarray(bounds)))
    want = np.asarray(ref.ref_msc_select(jnp.asarray(conc), jnp.asarray(bounds)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("C,D,n", [(5, 3, 10), (64, 8, 2048), (513, 5, 100)])
def test_closure_expand_sweep(C, D, n, rng):
    sorted_ids = jnp.asarray(
        np.sort(rng.choice(1 << 20, C, replace=False)).astype(np.int32))
    anc = jnp.asarray(rng.integers(-1, 1 << 20, (C, D)).astype(np.int32))
    q = jnp.asarray(rng.integers(0, 1 << 20, n).astype(np.int32))
    got = ops.closure_expand(q, sorted_ids, anc)
    want = ref.ref_closure_expand(q, sorted_ids, anc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("T,N", [(0, 5), (300, 7), (2048, 2048), (5000, 1300)])
@pytest.mark.parametrize("block", [256, 512])
def test_pair_search_windowed_matches_resident(T, N, block, rng):
    """The merge-path-partitioned reuse must equal the resident kernel and
    the numpy searchsorted oracle bit-exactly ('left' contract), at any
    table/query size — including tables past the resident VMEM dispatch."""
    hi = np.sort(rng.integers(0, 50, T).astype(np.int32))
    lo = rng.integers(0, 1000, T).astype(np.int32)
    order = np.lexsort((lo, hi))
    hi, lo = hi[order], lo[order]
    qh = rng.integers(0, 52, N).astype(np.int32)
    ql = rng.integers(-5, 1005, N).astype(np.int32)
    off = np.int64(np.iinfo(np.int32).min)
    key = hi.astype(np.int64) * (1 << 32) + (lo.astype(np.int64) - off)
    qkey = qh.astype(np.int64) * (1 << 32) + (ql.astype(np.int64) - off)
    want = np.searchsorted(key, qkey, side="left")
    got = np.asarray(ops.pair_search_windowed(
        jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(qh), jnp.asarray(ql),
        block=block))
    np.testing.assert_array_equal(got, want)
    if T:
        res = np.asarray(ops.pair_search(
            jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(qh),
            jnp.asarray(ql)))
        np.testing.assert_array_equal(res, want)


@pytest.mark.parametrize("n", [1, 100, 512, 1000, 5000])
@pytest.mark.parametrize("block", [256, 512])
@pytest.mark.parametrize("density", [0.0, 0.3, 1.0])
def test_stream_compact_sweep(n, block, density, rng):
    from repro.kernels.stream_compact import stream_compact_pallas

    mask = jnp.asarray(rng.random(n) < density)
    padded = ops._pad1(mask.astype(jnp.int32), block, np.int32(0))
    loc, cnt = stream_compact_pallas(padded, block=block, interpret=True)
    rloc, rcnt = ref.ref_stream_compact(padded, block)
    np.testing.assert_array_equal(np.asarray(loc), np.asarray(rloc))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(rcnt))
    # assembled wrapper == flatnonzero prefix
    want = np.flatnonzero(np.asarray(mask))
    for cap in (8, 256, 1 << 13):
        take, ok, total = ops.compact_indices(mask, cap, block=block)
        assert int(total) == len(want)
        np.testing.assert_array_equal(np.asarray(take)[np.asarray(ok)],
                                      want[:cap])


@pytest.mark.parametrize("n", [5, 513, 4096])
def test_interval_compact_fused(n, rng):
    p = jnp.asarray(rng.integers(0, 100, n), jnp.int32)
    o = jnp.asarray(rng.integers(0, 1 << 20, n), jnp.int32)
    params = jnp.asarray([10, 40, 0, 1 << 19], jnp.int32)
    want = np.flatnonzero(np.asarray(
        ref.ref_interval_filter(None, p, o, 10, 40, 0, 1 << 19, 0)))
    take, ok, total = ops.interval_compact(p, o, params, 256)
    assert int(total) == len(want)
    np.testing.assert_array_equal(np.asarray(take)[np.asarray(ok)], want[:256])


@pytest.mark.parametrize("n", [5, 513, 4096])
@pytest.mark.parametrize("density", [0.0, 0.5, 1.0])
def test_masked_interval_compact_fused(n, density, rng):
    """Tombstone-aware fused compaction == interval predicate AND liveness."""
    p = jnp.asarray(rng.integers(0, 100, n), jnp.int32)
    o = jnp.asarray(rng.integers(0, 1 << 20, n), jnp.int32)
    alive = jnp.asarray(rng.random(n) < density)
    params = jnp.asarray([10, 40, 0, 1 << 19], jnp.int32)
    want = np.flatnonzero(np.asarray(
        ref.ref_interval_filter(None, p, o, 10, 40, 0, 1 << 19, 0))
        & np.asarray(alive))
    take, ok, total = ops.masked_interval_compact(p, o, alive, params, 256)
    assert int(total) == len(want)
    np.testing.assert_array_equal(np.asarray(take)[np.asarray(ok)], want[:256])


@pytest.mark.parametrize("block", [512, 1024, 4096])
@pytest.mark.parametrize("chunk", [128, 256, 512])
@pytest.mark.parametrize("density", [0.0, 0.13, 1.0])
def test_stream_compact_chunked_sweep(block, chunk, density, rng):
    """Chunked-cumsum body == ref across block x chunk x density.

    The chunked rewrite must be bit-identical for every chunking of the
    tile — including blocks past the old 512 one-hot ceiling — and for the
    empty-output (density 0) and all-survivors (density 1) edges, where
    the dynamic-slice stores degenerate to nothing / the whole tile.
    """
    from repro.kernels.stream_compact import stream_compact_pallas

    n = block * 2 + block // 2  # partial final tile after padding
    mask = jnp.asarray(rng.random(n) < density)
    padded = ops._pad1(mask.astype(jnp.int32), block, np.int32(0))
    loc, cnt = stream_compact_pallas(padded, block=block, chunk=chunk,
                                     interpret=True)
    rloc, rcnt = ref.ref_stream_compact(padded, block)
    np.testing.assert_array_equal(np.asarray(loc), np.asarray(rloc))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(rcnt))


@pytest.mark.parametrize("block", [512, 4096])
@pytest.mark.parametrize("n", [100, 5000, 9000])
def test_compact_indices_large_blocks(block, n, rng):
    """The assembled wrapper is block-size invariant (4096 == 512 == ref)."""
    mask = jnp.asarray(rng.random(n) < 0.2)
    want = np.flatnonzero(np.asarray(mask))
    for cap in (8, 1 << 13):
        take, ok, total = ops.compact_indices(mask, cap, block=block)
        assert int(total) == len(want)
        np.testing.assert_array_equal(np.asarray(take)[np.asarray(ok)],
                                      want[:cap])


@pytest.mark.parametrize("block", [512, 4096])
@pytest.mark.parametrize("n", [513, 5000])
@pytest.mark.parametrize("density", [0.0, 0.4, 1.0])
def test_masked_interval_compact_block_sweep(n, block, density, rng):
    """Fused masked variant parity across the new block sizes."""
    p = jnp.asarray(rng.integers(0, 100, n), jnp.int32)
    o = jnp.asarray(rng.integers(0, 1 << 20, n), jnp.int32)
    alive = jnp.asarray(rng.random(n) < density)
    params = jnp.asarray([10, 40, 0, 1 << 19], jnp.int32)
    want = np.flatnonzero(np.asarray(
        ref.ref_interval_filter(None, p, o, 10, 40, 0, 1 << 19, 0))
        & np.asarray(alive))
    take, ok, total = ops.masked_interval_compact(p, o, alive, params, 256,
                                                  block=block)
    assert int(total) == len(want)
    np.testing.assert_array_equal(np.asarray(take)[np.asarray(ok)],
                                  want[:256])


@pytest.mark.parametrize("block", [512, 1024, 4096])
@pytest.mark.parametrize("da,db", [(0.0, 0.0), (0.2, 0.9), (1.0, 1.0),
                                   (0.0, 1.0)])
def test_dual_compact_sweep(block, da, db, rng):
    """Dual-mask kernel: both streams == ref, one grid pass.

    Covers asymmetric densities and the empty-output / all-survivors edges
    on each stream independently.
    """
    from repro.kernels.stream_compact import dual_compact_pallas

    n = block * 2
    ma = jnp.asarray((rng.random(n) < da).astype(np.int32))
    mb = jnp.asarray((rng.random(n) < db).astype(np.int32))
    la, ca, lb, cb = dual_compact_pallas(ma, mb, block=block, interpret=True)
    rla, rca, rlb, rcb = ref.ref_dual_compact(ma, mb, block)
    for got, want in ((la, rla), (ca, rca), (lb, rlb), (cb, rcb)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dual_compact_indices_wrapper(rng):
    """ops.dual_compact_indices == two compact_indices, one kernel pass."""
    n = 3000
    ma = jnp.asarray(rng.random(n) < 0.15)
    mb = jnp.asarray(rng.random(n) < 0.6)
    wa, wb = np.flatnonzero(np.asarray(ma)), np.flatnonzero(np.asarray(mb))
    for cap in (16, 1 << 12):
        ta, oka, tota, tb, okb, totb = ops.dual_compact_indices(
            ma, mb, cap)
        assert int(tota) == len(wa) and int(totb) == len(wb)
        np.testing.assert_array_equal(np.asarray(ta)[np.asarray(oka)],
                                      wa[:cap])
        np.testing.assert_array_equal(np.asarray(tb)[np.asarray(okb)],
                                      wb[:cap])


@given(st.integers(1, 6000), st.integers(0, 2**31 - 2),
       st.sampled_from([512, 1024, 4096]), st.sampled_from([128, 256]))
@settings(max_examples=20, deadline=None)
def test_stream_compact_chunked_property(n, seed, block, chunk):
    from repro.kernels.stream_compact import stream_compact_pallas

    rng = np.random.default_rng(seed)
    mask = jnp.asarray((rng.random(n) < rng.random()).astype(np.int32))
    padded = ops._pad1(mask, block, np.int32(0))
    loc, cnt = stream_compact_pallas(padded, block=block, chunk=chunk,
                                     interpret=True)
    rloc, rcnt = ref.ref_stream_compact(padded, block)
    np.testing.assert_array_equal(np.asarray(loc), np.asarray(rloc))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(rcnt))


def _sorted_pair_run(rng, n, key_space):
    """Random (hi, lo)-lex-sorted int32 run; small key_space → dense dups."""
    hi = rng.integers(0, key_space, n).astype(np.int32)
    lo = rng.integers(0, key_space, n).astype(np.int32)
    k = np.lexsort((lo, hi))
    return hi[k], lo[k]


@pytest.mark.parametrize("n,m", [(1, 1), (7, 100), (513, 513), (2048, 31),
                                 (1, 2000), (1000, 1000)])
@pytest.mark.parametrize("key_space", [3, 1 << 20])  # dup density sweep
def test_merge_gather_sweep(n, m, key_space, rng):
    """Merge-path kernel == ref oracle across sizes × duplicate densities."""
    ah, al = _sorted_pair_run(rng, n, key_space)
    bh, bl = _sorted_pair_run(rng, m, key_space)
    args = tuple(map(jnp.asarray, (ah, al, bh, bl)))
    got = np.asarray(ops.merge_gather(*args))
    want = np.asarray(ref.ref_merge_sorted(*args))
    np.testing.assert_array_equal(got, want)
    # the map is a permutation and the gathered keys are sorted + stable
    assert len(np.unique(got)) == n + m
    mh = np.where(got < n, ah[np.clip(got, 0, n - 1)],
                  bh[np.clip(got - n, 0, m - 1)])
    ml = np.where(got < n, al[np.clip(got, 0, n - 1)],
                  bl[np.clip(got - n, 0, m - 1)])
    key = mh.astype(np.int64) << 32 | ml.astype(np.int64)
    assert (np.diff(key) >= 0).all()


@pytest.mark.parametrize("n,m", [(64, 16), (517, 100), (1500, 1500)])
@pytest.mark.parametrize("tombstone_ratio", [0.0, 0.3, 1.0])
def test_merge_gather_masked_compaction(n, m, tombstone_ratio, rng):
    """Merge-everything-then-compact == host merge of pre-filtered runs.

    The device compaction path (core/delta.py) merges runs WITH their dead
    rows and drops them through the stream-compaction kernel afterwards;
    a stable merge followed by a stable filter must equal the merge of the
    filtered runs — the contract this pins across tombstone ratios.
    """
    from repro.core.index import merge_sorted

    def rows_run(k):
        hi, lo = _sorted_pair_run(rng, k, 50)
        rows = np.stack([rng.integers(0, 1 << 20, k).astype(np.int32),
                         hi, lo], axis=1)
        alive = rng.random(k) >= tombstone_ratio
        key = hi.astype(np.int64) << 32 | lo.astype(np.int64)
        return rows, alive, key

    a_rows, a_alive, a_key = rows_run(n)
    b_rows, b_alive, b_key = rows_run(m)
    gidx = np.asarray(ops.merge_gather(
        *map(jnp.asarray, (a_rows[:, 1], a_rows[:, 2],
                           b_rows[:, 1], b_rows[:, 2]))))
    alive = np.asarray(ops.two_source_gather(
        jnp.asarray(a_alive), jnp.asarray(b_alive), jnp.asarray(gidx)))
    n_live = int(a_alive.sum() + b_alive.sum())
    take, ok, total = ops.compact_indices(jnp.asarray(alive), max(n_live, 8))
    src = np.asarray(take)[:n_live]
    got = np.asarray(ops.two_source_gather(
        jnp.asarray(a_rows), jnp.asarray(b_rows), jnp.asarray(gidx[src])))
    assert int(total) == n_live
    want, _ = merge_sorted(a_rows[a_alive], a_key[a_alive],
                           b_rows[b_alive], b_key[b_alive])
    np.testing.assert_array_equal(got, np.asarray(want))


@pytest.mark.parametrize("n,m", [(256, 256), (300, 270), (1030, 5000),
                                 (4096, 256), (2000, 2000)])
@pytest.mark.parametrize("key_space", [3, 50, 1 << 20])  # dup density sweep
def test_merge_gather_partitioned_sweep(n, m, key_space, rng):
    """Diagonal-partitioned merge == ref oracle across sizes x dup density.

    Runs the partitioned kernel directly at a small block (256) so every
    case crosses several tile boundaries — including boundaries that land
    inside long duplicate-key runs, where the split search's stable
    A-before-B rule must agree with the per-element searches on both
    sides of the cut.
    """
    from repro.kernels.merge_sorted import merge_path_partitioned_pallas

    ah, al = _sorted_pair_run(rng, n, key_space)
    bh, bl = _sorted_pair_run(rng, m, key_space)
    args = tuple(map(jnp.asarray, (ah, al, bh, bl)))
    got = np.asarray(merge_path_partitioned_pallas(
        *args, block=256, interpret=True))[: n + m]
    want = np.asarray(ref.ref_merge_sorted(*args))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n,m", [(1100, 1100), (1024, 4096)])
@pytest.mark.parametrize("tombstone_ratio", [0.0, 0.3, 1.0])
def test_merge_gather_partitioned_masked_compaction(n, m, tombstone_ratio,
                                                    rng):
    """Partitioned merge + tombstone drop == host merge of filtered runs.

    The device-compaction contract (core/delta.py) re-pinned on the
    dispatch path that selects the partitioned kernel (both runs >= the
    1024 default block), across tombstone ratios including kill-everything.
    """
    from repro.core.index import merge_sorted

    def rows_run(k):
        hi, lo = _sorted_pair_run(rng, k, 50)
        rows = np.stack([rng.integers(0, 1 << 20, k).astype(np.int32),
                         hi, lo], axis=1)
        alive = rng.random(k) >= tombstone_ratio
        key = hi.astype(np.int64) << 32 | lo.astype(np.int64)
        return rows, alive, key

    a_rows, a_alive, a_key = rows_run(n)
    b_rows, b_alive, b_key = rows_run(m)
    ops.merge_gather.clear_cache()  # counters bump at trace time only
    ops.reset_pass_counters()
    gidx = np.asarray(ops.merge_gather(
        *map(jnp.asarray, (a_rows[:, 1], a_rows[:, 2],
                           b_rows[:, 1], b_rows[:, 2]))))
    assert ops.pass_counters["merge_partitioned"] >= 1  # dispatch took it
    alive = np.asarray(ops.two_source_gather(
        jnp.asarray(a_alive), jnp.asarray(b_alive), jnp.asarray(gidx)))
    n_live = int(a_alive.sum() + b_alive.sum())
    take, ok, total = ops.compact_indices(jnp.asarray(alive), max(n_live, 8))
    src = np.asarray(take)[:n_live]
    got = np.asarray(ops.two_source_gather(
        jnp.asarray(a_rows), jnp.asarray(b_rows), jnp.asarray(gidx[src])))
    assert int(total) == n_live
    want, _ = merge_sorted(a_rows[a_alive], a_key[a_alive],
                           b_rows[b_alive], b_key[b_alive])
    np.testing.assert_array_equal(got, np.asarray(want))


@given(st.integers(256, 1200), st.integers(256, 1200),
       st.integers(0, 2**31 - 2))
@settings(max_examples=20, deadline=None)
def test_merge_gather_partitioned_property(n, m, seed):
    from repro.kernels.merge_sorted import merge_path_partitioned_pallas

    rng = np.random.default_rng(seed)
    ah, al = _sorted_pair_run(rng, n, int(rng.integers(2, 1 << 16)))
    bh, bl = _sorted_pair_run(rng, m, int(rng.integers(2, 1 << 16)))
    args = tuple(map(jnp.asarray, (ah, al, bh, bl)))
    got = np.asarray(merge_path_partitioned_pallas(
        *args, block=256, interpret=True))[: n + m]
    np.testing.assert_array_equal(got, np.asarray(ref.ref_merge_sorted(*args)))


def test_two_source_gather_degenerate_sources(rng):
    """Empty base (fully-compacted-away store) and absent delta both work."""
    rows = jnp.asarray(rng.integers(0, 100, (16, 3)).astype(np.int32))
    idx = jnp.asarray(np.arange(16, dtype=np.int32))
    empty = jnp.zeros((0, 3), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(ops.two_source_gather(empty, rows, idx)), np.asarray(rows))
    np.testing.assert_array_equal(
        np.asarray(ops.two_source_gather(rows, None, idx)), np.asarray(rows))
    np.testing.assert_array_equal(
        np.asarray(ops.two_source_gather(rows, empty, idx)), np.asarray(rows))


@given(st.integers(1, 300), st.integers(1, 300), st.integers(0, 2**31 - 2))
@settings(max_examples=25, deadline=None)
def test_merge_gather_property(n, m, seed):
    rng = np.random.default_rng(seed)
    ah, al = _sorted_pair_run(rng, n, int(rng.integers(2, 1 << 16)))
    bh, bl = _sorted_pair_run(rng, m, int(rng.integers(2, 1 << 16)))
    args = tuple(map(jnp.asarray, (ah, al, bh, bl)))
    np.testing.assert_array_equal(
        np.asarray(ops.merge_gather(*args)),
        np.asarray(ref.ref_merge_sorted(*args)))


@given(st.integers(1, 200), st.integers(1, 300), st.integers(0, 2**31 - 2))
@settings(max_examples=25, deadline=None)
def test_pair_search_property(T, n, seed):
    rng = np.random.default_rng(seed)
    fps = np.sort(rng.choice(1 << 50, T, replace=False))
    thi = jnp.asarray((fps >> 31).astype(np.int32))
    tlo = jnp.asarray((fps & ((1 << 31) - 1)).astype(np.int32))
    qs = rng.choice(1 << 50, n)
    qhi = jnp.asarray((qs >> 31).astype(np.int32))
    qlo = jnp.asarray((qs & ((1 << 31) - 1)).astype(np.int32))
    got = np.asarray(ops.pair_search(thi, tlo, qhi, qlo))
    want = np.searchsorted(fps, qs, side="left")
    np.testing.assert_array_equal(got, want)
