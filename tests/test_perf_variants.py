"""Beyond-paper optimization variants must match their baselines exactly
(or within dtype tolerance) — these guard the §Perf hillclimb results."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch


def test_blockwise_attention_matches_naive():
    from repro.models import lm as lm_lib

    cfg_n = get_arch("gemma3-12b").reduced_config()  # local:global mix
    cfg_b = dataclasses.replace(cfg_n, attn_impl="blockwise")
    key = jax.random.key(0)
    params = lm_lib.init_params(key, cfg_n)
    tok = jax.random.randint(key, (2, 32), 0, cfg_n.vocab)
    xn, _ = lm_lib.forward(params, tok, cfg_n)
    xb, _ = lm_lib.forward(params, tok, cfg_b)
    np.testing.assert_allclose(np.asarray(xn), np.asarray(xb), atol=2e-4)


@pytest.mark.parametrize("window,is_global", [(0, True), (64, False), (64, True)])
def test_flash_kernel_fwd_bwd(window, is_global, rng):
    from repro.kernels.flash_attention import flash_mha
    from repro.models.attention import _causal_mask, _sdpa

    B, S, H, KV, hd = 2, 128, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))

    def ref(q, k, v):
        mask = _causal_mask(S, S)
        if window > 0:
            qi = jnp.arange(S)[:, None]
            kj = jnp.arange(S)[None, :]
            mask = mask & (jnp.bool_(is_global) | (kj > qi - window))
        return _sdpa(q, k, v, mask)

    got = flash_mha(q, k, v, jnp.bool_(is_global), window, 32, 64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref(q, k, v)),
                               atol=2e-5)
    w = jnp.asarray(rng.normal(size=(hd,)).astype(np.float32))
    g1 = jax.grad(lambda *a: (flash_mha(*a, jnp.bool_(is_global), window, 32, 64) * w).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (ref(*a) * w).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_equiformer_restrict_exact(rng):
    from repro.data.graphs import make_molecules
    from repro.models.gnn import equiformer

    mol = make_molecules(n_graphs=3, nodes_per=8, edges_per=16)
    mj = {k: jnp.asarray(v) for k, v in mol.items() if k != "n_graphs"}
    cfg0 = equiformer.EquiformerConfig(n_layers=2, channels=8, l_max=4,
                                       edge_chunks=2, n_out=1, n_heads=2)
    p = equiformer.init_params(jax.random.key(1), cfg0)
    o0 = equiformer.forward(p, mj, cfg0)
    o1 = equiformer.forward(
        p, mj, dataclasses.replace(cfg0, rotate_restrict=True))
    np.testing.assert_allclose(np.asarray(o0), np.asarray(o1), rtol=1e-5,
                               atol=1e-6)
    o2 = equiformer.forward(
        p, mj, dataclasses.replace(cfg0, rotate_restrict=True,
                                   edge_dtype="bfloat16"))
    rel = np.abs(np.asarray(o0) - np.asarray(o2)).max() / (
        np.abs(np.asarray(o0)).max() + 1e-9)
    assert rel < 0.05


def test_mind_sharded_topk_subprocess():
    """Sharded two-stage retrieval == single-device reference (8 devices)."""
    from tests.test_distributed import _NEW_JAX, _run

    if not _NEW_JAX:
        pytest.skip("multi-device subprocess test needs jax>=0.6 "
                    "(0.4.x compat path too slow for tier-1)")

    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.models.recsys import mind
from repro.utils.jaxcompat import make_mesh
cfg = mind.MINDConfig(n_items=1024, embed_dim=16, hist_len=10)
mesh = make_mesh((2, 4), ('data', 'model'))
params = mind.init_params(jax.random.key(0), cfg)
rng = np.random.default_rng(0)
hist = jnp.asarray(rng.integers(-1, 1024, (2, 10)), jnp.int32)
cand = jnp.asarray(rng.choice(1024, 512, replace=False), jnp.int32)
cat = jnp.asarray(rng.integers(0, 64, 512), jnp.int32)
rv, ri = jax.jit(mind.make_serve_step(cfg, topk=16))(
    params, hist, cand, cat, jnp.int32(0), jnp.int32(32))
sv, si = jax.jit(mind.make_serve_step_sharded(cfg, mesh, topk=16))(
    params, hist, cand, cat, jnp.int32(0), jnp.int32(32))
np.testing.assert_allclose(np.sort(np.asarray(rv), axis=1),
                           np.sort(np.asarray(sv), axis=1), rtol=1e-5)
for r, s in zip(np.asarray(ri), np.asarray(si)):
    assert set(r.tolist()) == set(s.tolist())
print('sharded retrieval OK')
"""
    )
    assert "sharded retrieval OK" in out
