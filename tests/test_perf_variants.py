"""Beyond-paper optimization variants must match their baselines exactly
(or within dtype tolerance) — these guard the §Perf hillclimb results."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch


def test_blockwise_attention_matches_naive():
    from repro.models import lm as lm_lib

    cfg_n = get_arch("gemma3-12b").reduced_config()  # local:global mix
    cfg_b = dataclasses.replace(cfg_n, attn_impl="blockwise")
    key = jax.random.key(0)
    params = lm_lib.init_params(key, cfg_n)
    tok = jax.random.randint(key, (2, 32), 0, cfg_n.vocab)
    xn, _ = lm_lib.forward(params, tok, cfg_n)
    xb, _ = lm_lib.forward(params, tok, cfg_b)
    np.testing.assert_allclose(np.asarray(xn), np.asarray(xb), atol=2e-4)


def test_equiformer_restrict_exact(rng):
    from repro.data.graphs import make_molecules
    from repro.models.gnn import equiformer

    mol = make_molecules(n_graphs=3, nodes_per=8, edges_per=16)
    mj = {k: jnp.asarray(v) for k, v in mol.items() if k != "n_graphs"}
    cfg0 = equiformer.EquiformerConfig(n_layers=2, channels=8, l_max=4,
                                       edge_chunks=2, n_out=1, n_heads=2)
    p = equiformer.init_params(jax.random.key(1), cfg0)
    o0 = equiformer.forward(p, mj, cfg0)
    o1 = equiformer.forward(
        p, mj, dataclasses.replace(cfg0, rotate_restrict=True))
    np.testing.assert_allclose(np.asarray(o0), np.asarray(o1), rtol=1e-5,
                               atol=1e-6)
    o2 = equiformer.forward(
        p, mj, dataclasses.replace(cfg0, rotate_restrict=True,
                                   edge_dtype="bfloat16"))
    rel = np.abs(np.asarray(o0) - np.asarray(o2)).max() / (
        np.abs(np.asarray(o0)).max() + 1e-9)
    assert rel < 0.05
