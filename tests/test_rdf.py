"""N-Triples parser/writer + generator sanity."""
import numpy as np

from repro.core.engine import KnowledgeBase
from repro.core.query import Pattern
from repro.rdf.generator import generate_lubm
from repro.rdf.parser import parse_ntriples, write_ntriples

NT = """
# a tiny TBox + ABox in N-Triples
<http://ex/Professor> <http://www.w3.org/2000/01/rdf-schema#subClassOf> <http://ex/Faculty> .
<http://ex/Faculty> <http://www.w3.org/2000/01/rdf-schema#subClassOf> <http://ex/Person> .
<http://ex/teaches> <http://www.w3.org/2000/01/rdf-schema#domain> <http://ex/Faculty> .
<http://ex/bernd> <http://www.w3.org/1999/02/22-rdf-syntax-ns#type> <http://ex/Professor> .
<http://ex/hubert> <http://ex/teaches> <http://ex/course1> .
_:b1 <http://ex/name> "anonymous"@en .
"""


def test_parse_example_from_paper():
    """The paper's Example 1: bernd (explicit) and hubert (domain-derived)
    are both FacultyMember/Faculty answers."""
    ds, onto = parse_ntriples(NT)
    assert ds.n_triples == 3
    K = KnowledgeBase.build(ds)
    res = {
        m: K.answers([Pattern("?x", "rdf:type", "<http://ex/Faculty>")], mode=m)
        for m in ("litemat", "full", "rewrite")
    }
    assert res["litemat"] == res["full"] == res["rewrite"]
    ids = K.kb.locate(["<http://ex/bernd>", "<http://ex/hubert>"])
    assert {(int(ids[0]),), (int(ids[1]),)} <= res["litemat"]


def test_writer_roundtrip():
    ds, _ = parse_ntriples(NT)
    text = write_ntriples(ds)
    ds2, _ = parse_ntriples(text)
    a = set(map(tuple, ds.triples().tolist()))
    b = set(map(tuple, ds2.triples().tolist()))
    assert a == b


def test_generator_scaling_and_determinism():
    a = generate_lubm(1, seed=9)
    b = generate_lubm(1, seed=9)
    np.testing.assert_array_equal(a.s, b.s)
    c = generate_lubm(2, seed=9)
    assert c.n_triples > 1.6 * a.n_triples
    # LUBM-ish scale: ~100-140K triples per university
    assert 80_000 < a.n_triples < 180_000
