"""Materialization vs brute-force RDFS oracles."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.abox import encode_obe
from repro.core.closure import full_materialize
from repro.core.materialize import DeviceTBox, compact_rows, lite_materialize
from repro.core.tbox import Ontology, build_tbox
from repro.rdf.generator import generate_random_abox


@st.composite
def small_kb(draw):
    nc = draw(st.integers(3, 12))
    np_ = draw(st.integers(2, 6))
    concepts = [f"C{i}" for i in range(nc)]
    props = [f"p{i}" for i in range(np_)]
    subclass = [
        (concepts[i], concepts[draw(st.integers(0, i - 1))]) for i in range(1, nc)
    ]
    subprop = [(props[i], props[draw(st.integers(0, i - 1))]) for i in range(1, np_)]
    domain, range_ = {}, {}
    for p in props:
        if draw(st.booleans()):
            domain[p] = [concepts[draw(st.integers(0, nc - 1))]]
        if draw(st.booleans()):
            range_[p] = [concepts[draw(st.integers(0, nc - 1))]]
    onto = Ontology(concepts=concepts, properties=props, subclass=subclass,
                    subprop=subprop, domain=domain, range_=range_)
    seed = draw(st.integers(0, 10_000))
    return onto, seed


def _oracle_closure(kb, tbox):
    """Pure-Python RDFS fixpoint over encoded triples (rules rdfs2/3/5/7/9/11,
    synthetic roots excluded, exactly the fragment the system targets)."""
    cenc, penc = tbox.concepts, tbox.properties
    canc = {int(cenc.ids[i]): {int(cenc.ids[a]) for a in cenc.tax.dag_ancestors(i)} - {0}
            for i in range(cenc.n)}
    panc = {int(penc.ids[i]): {int(penc.ids[a]) for a in penc.tax.dag_ancestors(i)} - {0}
            for i in range(penc.n)}
    dom = {int(k): {int(v) for v in row if v >= 0}
           for k, row in zip(tbox.dr_prop_ids, tbox.domain_table)}
    rng_ = {int(k): {int(v) for v in row if v >= 0}
            for k, row in zip(tbox.dr_prop_ids, tbox.range_table)}
    T = tbox.rdf_type_id

    triples = {tuple(map(int, row)) for row in np.asarray(kb.spo)}
    changed = True
    while changed:
        changed = False
        new = set()
        for s, p, o in triples:
            if p == T:
                for a in canc.get(o, ()):
                    new.add((s, T, a))
            else:
                for pa in panc.get(p, ()):
                    new.add((s, pa, o))
                for d in dom.get(p, ()):
                    new.add((s, T, d))
                for r in rng_.get(p, ()):
                    new.add((o, T, r))
        if not new <= triples:
            triples |= new
            changed = True
    return triples


@given(small_kb())
@settings(max_examples=15, deadline=None)
def test_full_closure_matches_oracle(kb_spec):
    onto, seed = kb_spec
    raw = generate_random_abox(onto, n_instances=30, n_type_triples=25,
                               n_prop_triples=40, seed=seed)
    tbox = build_tbox(onto)
    kb = encode_obe(raw, tbox)
    dtb = DeviceTBox.build(tbox)
    out, valid, stats = full_materialize(kb, dtb)
    got = {tuple(map(int, r)) for r in np.asarray(compact_rows(out, valid))}
    want = _oracle_closure(kb, tbox)
    assert got == want
    assert stats["n_closure"] == len(want)


@given(small_kb())
@settings(max_examples=15, deadline=None)
def test_msc_is_minimal_and_equivalent(kb_spec):
    """Lite-materialized types must (a) entail the same closure as the full
    set and (b) contain no redundant (ancestor-of-another-type) concept."""
    onto, seed = kb_spec
    raw = generate_random_abox(onto, n_instances=25, n_type_triples=20,
                               n_prop_triples=30, seed=seed)
    tbox = build_tbox(onto)
    kb = encode_obe(raw, tbox)
    dtb = DeviceTBox.build(tbox)
    out, valid, _ = lite_materialize(kb, dtb)
    lite = np.asarray(compact_rows(out, valid))

    oracle = _oracle_closure(kb, tbox)
    cenc = tbox.concepts
    strict_desc = {}
    for i in range(cenc.n):
        me = int(cenc.ids[i])
        strict_desc[me] = {int(cenc.ids[d]) for d in cenc.tax.dag_descendants(i)} - {me}

    T = tbox.rdf_type_id
    # group lite types per instance
    per_inst = {}
    for s, p, o in lite:
        if p == T:
            per_inst.setdefault(int(s), set()).add(int(o))
    oracle_types = {}
    for s, p, o in oracle:
        if p == T:
            oracle_types.setdefault(int(s), set()).add(int(o))

    for inst, types in per_inst.items():
        # (a) upward closure of MSC == oracle types (minus roots)
        closure = set()
        for t in types:
            closure.add(t)
            node = cenc._id_to_node[t]
            closure |= {int(cenc.ids[a]) for a in cenc.tax.dag_ancestors(node)} - {0}
        assert closure == oracle_types.get(inst, set())
        # (b) minimality: no kept type subsumes another kept type
        for t in types:
            assert not (strict_desc[t] & types), (inst, types)


def test_lubm_lite_mat_matches_paper(lubm_kb):
    """Paper Table IV: LUBM adds ~0%, deletes 0 (single most-specific types)."""
    K, raw = lubm_kb
    st_ = K.lite_stats
    assert st_["n_deleted_explicit"] == 0
    added_pct = 100.0 * st_["n_added_implicit"] / raw.n_triples
    assert added_pct < 2.0
    # Table V: full materialization adds ~38% on LUBM
    assert 30.0 < K.full_stats["added_pct"] < 50.0
