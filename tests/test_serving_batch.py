"""Micro-batched serving + pagination: correctness, isolation, bugfixes.

The batching contract under test: a burst of same-signature requests
answered through the runtime's coalesced path must be BIT-IDENTICAL to
per-request ``serve()`` in every mode, each member must carry its own
Outcome (version, stale, trace_id), and a member that faults must not
poison its batchmates.  Pagination: the union of all pages equals the
unpaginated answer set at the pinned version, and a cursor whose version
was retired degrades to a stale fresh-pin page instead of erroring.
Plus the runtime bugfix sweep: the start() double-start race, the
shed-path trace leak, and the unbounded latency list.
"""
import threading

import numpy as np

from repro.core.engine import KnowledgeBase, PAPER_QUERIES
from repro.core.query import QueryEngine
from repro.obs.export import validate_trace
from repro.obs.trace import Tracer
from repro.serving.runtime import Cursor, ServingRuntime
from repro.testing import faults


def _burst(rt, queries, **kw):
    futs = [rt.submit(q, **kw) for q in queries]
    return [f.result() for f in futs]


def _fresh_engine(K, mode="litemat"):
    """A private engine — the KB's cached one is shared session state."""
    return QueryEngine(kb=K.kb, spo=K._base_store(mode), mode=mode,
                       dtb=K.dtb, view=K.view(mode))


# -- batched answers == solo answers ----------------------------------------


def test_batched_answers_match_solo_across_modes(lubm_kb):
    K, _ = lubm_kb
    qs = list(PAPER_QUERIES.values())
    rt = ServingRuntime(K, modes=("litemat", "full", "rewrite"),
                        n_workers=1, batch_window_s=0.05, max_batch=16)
    with rt:
        for mode in ("litemat", "full", "rewrite"):
            solo = [rt.serve(q, mode=mode) for q in qs]
            assert all(o.ok for o in solo)
            burst = _burst(rt, [qs[i % len(qs)] for i in range(16)],
                           mode=mode)
            assert all(o.ok for o in burst)
            for i, out in enumerate(burst):
                assert out.answers == solo[i % len(qs)].answers, mode
                assert out.version is not None
        assert rt.stats["batched"] > 0
        occ = rt.metrics.histogram("serving/batch_size",
                                   kind="query").summary()
        assert occ["n"] > 0 and occ["max"] >= 2


def test_batch_members_carry_own_outcomes(lubm_kb):
    """Every member of a coalesced batch gets its own version / trace_id,
    and the batched spans export as well-formed traces."""
    K, _ = lubm_kb
    qs = list(PAPER_QUERIES.values())
    tracer = Tracer()
    rt = ServingRuntime(K, modes=("litemat",), n_workers=1,
                        batch_window_s=0.05, max_batch=8, tracer=tracer)
    with rt:
        outs = _burst(rt, [qs[i % len(qs)] for i in range(8)])
    assert all(o.ok for o in outs)
    ids = [o.trace_id for o in outs]
    assert len(set(ids)) == len(ids) and all(ids)
    versions = {o.version for o in outs}
    assert len(versions) == 1  # one read-only burst, one consistent version
    by_id = {t.trace_id: t for t in tracer.finished_traces()}
    saw_batched = False
    for o in outs:
        tr = by_id[o.trace_id]
        assert validate_trace(tr.to_dict()) == []
        for sp in tr.find("attempt"):
            if sp.attrs.get("batched"):
                saw_batched = True
                assert sp.attrs["batch_size"] >= 2
    assert saw_batched  # the burst actually exercised the coalesced path


def test_sharded_batch_matches_solo(lubm_kb):
    """The sharded fan-out under the runtime: batched == solo answers."""
    from repro.core.shard import ShardedKB

    _, raw = lubm_kb
    skb = ShardedKB.build(raw, n_shards=2)
    qs = [PAPER_QUERIES["Q1"], PAPER_QUERIES["Q3"]]
    rt = ServingRuntime(skb, modes=("litemat",), n_workers=1,
                        batch_window_s=0.05, max_batch=8)
    with rt:
        solo = [rt.serve(q) for q in qs]
        outs = _burst(rt, [qs[i % 2] for i in range(6)])
    assert all(o.ok for o in solo + outs)
    for i, o in enumerate(outs):
        assert o.answers == solo[i % 2].answers


# -- fault isolation ---------------------------------------------------------


def test_batch_member_fault_does_not_poison_batchmates(lubm_kb):
    """One member hitting the serving.execute fault gate retries ALONE;
    every batchmate still answers ok from the shared dispatch."""
    K, _ = lubm_kb
    qs = list(PAPER_QUERIES.values())
    rt = ServingRuntime(K, modes=("litemat",), n_workers=1,
                        batch_window_s=0.05, max_batch=8, max_retries=2)
    with rt:
        expected = [rt.serve(q) for q in qs]
        with faults.inject() as inj:
            inj.arm("serving.execute", exc=faults.FaultError, after=0,
                    times=1)  # exactly one gate check faults
            outs = _burst(rt, [qs[i % len(qs)] for i in range(8)])
            assert inj.fired("serving.execute") == 1
    assert all(o.ok for o in outs)
    for i, o in enumerate(outs):
        assert o.answers == expected[i % len(qs)].answers


def test_whole_batch_failure_degrades_to_solo(lubm_kb):
    """A batch-level execution error falls every member back to its own
    retry ladder — outcomes stay ok, nothing leaks the batch exception."""
    K, _ = lubm_kb
    qs = list(PAPER_QUERIES.values())
    rt = ServingRuntime(K, modes=("litemat",), n_workers=1,
                        batch_window_s=0.05, max_batch=8)
    with rt:
        expected = [rt.serve(q) for q in qs]
        boom = {"armed": True}
        orig = rt.registry.pin

        def bad_pin(*a, **kw):
            pin = orig(*a, **kw)
            if boom.pop("armed", None):
                class _BadPin:
                    version = pin.version
                    stale = pin.stale

                    def query_batch(self, *a, **kw):
                        raise RuntimeError("injected batch crash")

                    def release(self):
                        pin.release()
                return _BadPin()
            return pin

        rt.registry.pin = bad_pin
        try:
            outs = _burst(rt, [qs[i % len(qs)] for i in range(8)])
        finally:
            rt.registry.pin = orig
    assert all(o.ok for o in outs)
    for i, o in enumerate(outs):
        assert o.answers == expected[i % len(qs)].answers
    assert rt.metrics.counter_value("serving/batch_fallback",
                                    reason="batch_error") >= 1


# -- pagination --------------------------------------------------------------


def test_page_union_equals_unpaginated(lubm_kb):
    K, _ = lubm_kb
    rt = ServingRuntime(K, modes=("litemat",), n_workers=2)
    with rt:
        for q in (PAPER_QUERIES["Q1"], PAPER_QUERIES["Q3"]):
            full = rt.serve(q)
            page = rt.serve(q, page_size=7)
            assert page.ok and page.total == len(full.answers)
            got = list(page.answers)
            versions = {page.version}
            while page.cursor is not None:
                assert isinstance(page.cursor, Cursor)
                page = rt.serve(q, cursor=page.cursor)
                assert page.ok
                got += list(page.answers)
                versions.add(page.version)
            assert len(versions) == 1  # every page pinned the same version
            assert len(got) == len(set(got))  # stable order: no dup rows
            assert set(got) == full.answers


def test_cursor_repins_same_version_or_reports_stale(lubm_kb):
    _, raw = lubm_kb
    K = KnowledgeBase.build(raw)  # private KB: this test moves the store
    s, p, o = (np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o))
    rt = ServingRuntime(K, modes=("litemat",), n_workers=1)
    with rt:
        q = PAPER_QUERIES["Q1"]
        first = rt.serve(q, page_size=5)
        assert first.ok and first.cursor is not None and not first.stale
        # unchanged store: page 2 re-pins the exact version, not-stale
        second = rt.serve(q, cursor=first.cursor)
        assert second.ok and second.version == first.version
        assert not second.stale

        # the store moves and the old version is retired (no refs held):
        # the continuation degrades to a fresh pin tagged stale
        rt.insert((s[:32], p[:32], o[:32]), auto_compact=False)
        assert first.version not in rt.registry.live_versions()
        third = rt.serve(q, cursor=second.cursor)
        assert third.ok and third.stale
        assert third.version != first.version
    assert rt.metrics.counter_value("snapshot/pin_path",
                                    path="cursor_miss") >= 1


# -- server kinds under the runtime ------------------------------------------


def test_server_fanout_under_runtime(lubm_kb):
    """class_members / class_prop_join ride the runtime's queue, batch by
    concatenation, and match the direct QueryServer answers."""
    from repro.serving.engine import QueryServer

    K, _ = lubm_kb
    srv = QueryServer(K, topk=32)
    names = ["Professor", "Student", "Department", "Chair"]
    want_counts, _ = srv.class_members(names)
    rt = ServingRuntime(K, modes=("litemat",), n_workers=1,
                        batch_window_s=0.05, max_batch=8, server_topk=32)
    with rt:
        out = rt.class_members(names)
        assert out.ok and out.version is not None
        assert np.array_equal(out.answers[0], want_counts)
        # a burst of single-class requests coalesces into one dispatch and
        # still splits the planes back per request
        futs = [rt.submit_class_members([n]) for n in names]
        outs = [f.result() for f in futs]
        assert all(o.ok for o in outs)
        for n, o, want in zip(names, outs, want_counts):
            assert int(o.answers[0][0]) == int(want), n
        jn = rt.class_prop_join(["Professor"], ["worksFor"])
        want_j, _ = srv.class_prop_join(["Professor"], ["worksFor"])
        assert jn.ok and int(jn.answers[0][0]) == int(want_j[0])


# -- runtime bugfix sweep ----------------------------------------------------


def test_start_is_race_free(lubm_kb):
    """S1 regression: concurrent first submits must spawn ONE worker pool."""
    K, _ = lubm_kb
    rt = ServingRuntime(K, modes=("litemat",), n_workers=2)
    barrier = threading.Barrier(8)

    def hammer():
        barrier.wait()
        rt.start()

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    try:
        assert len(rt._workers) == 2
    finally:
        rt.stop()
    assert rt._workers == []


def test_shed_trace_closes_queue_span(lubm_kb):
    """S2 regression: a shed request's queue span must finish — its trace
    exports without the validator's leaked-span rejection."""
    K, _ = lubm_kb
    tracer = Tracer()
    rt = ServingRuntime(K, modes=("litemat",), n_workers=1, max_queue=1,
                        max_batch=1, tracer=tracer)
    with rt:
        with faults.inject() as inj:
            inj.arm("serving.execute", exc=None, delay_s=0.2, times=2)
            futs = [rt.submit(PAPER_QUERIES["Q1"]) for _ in range(8)]
            outs = [f.result() for f in futs]
    shed = [o for o in outs if o.status == "shed"]
    assert shed, "queue of 1 under a blocked worker must shed"
    by_id = {t.trace_id: t for t in tracer.finished_traces()}
    for o in shed:
        tr = by_id[o.trace_id]
        assert validate_trace(tr.to_dict()) == []
        (span,) = tr.find("queue")
        assert span.t1 >= 0 and not span.attrs.get("dangling")


def test_validator_rejects_leaked_span():
    """The tightened invariant itself: a non-root span left open at
    finish_trace is marked dangling and fails validation."""
    tracer = Tracer()
    tr = tracer.new_trace()
    root = tracer.start_root(tr, "request")
    tr.new_span("queue", root.span_id, {})  # never finished
    tracer.finish_trace(tr)
    errors = validate_trace(tr.to_dict())
    assert any("leaked span" in e for e in errors)


def test_latency_stats_is_bounded_state(lubm_kb):
    """S3 regression: latency_stats derives from the registry histogram —
    no per-request list grows on the runtime."""
    K, _ = lubm_kb
    rt = ServingRuntime(K, modes=("litemat",), n_workers=1)
    with rt:
        for _ in range(4):
            assert rt.serve(PAPER_QUERIES["Q1"]).ok
    assert not hasattr(rt, "_latencies")
    stats = rt.latency_stats()
    assert stats["n"] == 4
    assert stats["p50_ms"] > 0 and stats["p99_ms"] >= stats["p50_ms"]
    assert rt.latency_stats(status="error") == dict(n=0)


# -- planner feedback (S4) ---------------------------------------------------


def test_observed_selectivity_flips_inl_decision(lubm_kb):
    """Observations — keyed by (sig, probe-constant bucket) — both enable
    and VETO the INL conversion for exactly their own probe side, while a
    different bucket's observation (the old aliasing) is never consulted."""
    K, _ = lubm_kb
    eng = _fresh_engine(K)
    q4 = PAPER_QUERIES["Q4"]
    planned = eng._plan(q4, None)
    sigs, caps, buckets = planned[0], planned[2], planned[8]
    (j,) = [i for i, s in enumerate(sigs) if s.strategy == "inl"]
    inl_sig, base_cap, inl_bucket = sigs[j], caps[j], buckets[j]

    # a probe-side estimate too big for the heuristic: no conversion
    eng.inl_factor = 64
    sigs2, *_ = eng._plan(q4, None)
    assert not any(s.strategy == "inl" for s in sigs2)

    # one observation of the probe's true (tiny) output flips it back on:
    # observed_rows * factor undercuts the merge-side count
    store_n = max(eng.view.n, 1)
    eng.observed_selectivity[(inl_sig, inl_bucket)] = 10 / store_n
    sigs3, _, caps3, *_ = eng._plan(q4, None)
    (k,) = [i for i, s in enumerate(sigs3) if s.strategy == "inl"]
    assert sigs3[k] == inl_sig
    # ... and the capacity tracks the observation, not the est*32 guess
    assert caps3[k] < base_cap

    # a HUGE observation under a DIFFERENT probe-constant bucket (another
    # probe side that happens to share this sig — Q3's Professors vs Q4's
    # Chairs) is simply not consulted: the heuristic conversion stands
    eng.inl_factor = 8
    eng.observed_selectivity.clear()
    eng.observed_selectivity[(inl_sig, ("other-probe",))] = 1.0
    sigs4, *_ = eng._plan(q4, None)
    assert any(s.strategy == "inl" for s in sigs4)

    # ... while the SAME bucket's huge observation VETOES the conversion
    # the heuristic would have made — the regression the bare-sig keying
    # made impossible (an aliased store could only ever turn INL on)
    eng.observed_selectivity[(inl_sig, inl_bucket)] = 1.0
    sigs5, *_ = eng._plan(q4, None)
    assert not any(s.strategy == "inl" for s in sigs5)

    # the flipped plan answers identically to the oracle
    eng.inl_factor = 64
    eng.observed_selectivity.clear()
    eng.observed_selectivity[(inl_sig, inl_bucket)] = 10 / store_n
    rows, _ = eng.run(q4)
    got = {tuple(r) for r in rows.tolist()}
    assert got == K.answers(q4, mode="litemat")


def test_batch_caps_observation_shrinks_and_grows(lubm_kb):
    """Batched capacity unification: complete per-member evidence lets the
    observed floor SHRINK an over-provisioned cap (previously impossible
    under sig aliasing); partial evidence stays grow-only."""
    K, _ = lubm_kb
    eng = _fresh_engine(K)
    planned = eng._plan(PAPER_QUERIES["Q1"], None)
    store_n = max(eng.view.n, 1)
    key0 = (planned[0][0], planned[8][0])

    # an over-provisioned member: planner caps inflated 16x
    p_big = (planned[0], planned[1], [c * 16 for c in planned[2]],
             planned[3] * 16, *planned[4:])
    caps_big, _ = eng._batch_caps([p_big])
    assert caps_big == p_big[2]  # no observations: planner caps stand

    # complete evidence (the only member is observed): the tiny observed
    # floor REPLACES the inflated cap — the capacity shrinks
    eng.observed_selectivity[key0] = 1 / store_n
    caps_shrunk, _ = eng._batch_caps([p_big])
    assert caps_shrunk[0] < caps_big[0]
    assert caps_shrunk[0] == eng._bucket(int(1 * eng.slack) + 16)

    # a huge observation raises the cap to its floor (growth still works)
    caps0, _ = eng._batch_caps([planned])
    eng.observed_selectivity[key0] = (caps0[0] * 8) / store_n
    caps1, join1 = eng._batch_caps([planned])
    assert caps1[0] > caps0[0]
    assert join1 >= max(caps1)

    # partial evidence: a second member under an UNOBSERVED bucket blocks
    # the shrink — the unified cap may only grow past the planner max
    eng.observed_selectivity[key0] = 1 / store_n
    p_other = (*planned[:8],
               tuple(("unobserved",) for _ in planned[8]))
    caps_mixed, _ = eng._batch_caps([p_big, p_other])
    assert caps_mixed[0] == max(p_big[2][0], planned[2][0])


def test_engine_run_batch_matches_run(lubm_kb):
    """Engine-level batching: dedupe + grouped dispatch returns the same
    rows as per-request run() for a mixed same/different-signature load."""
    K, _ = lubm_kb
    for mode in ("litemat", "full", "rewrite"):
        eng = _fresh_engine(K, mode)
        qs = list(PAPER_QUERIES.values())
        reqs = [(qs[i % len(qs)], None) for i in range(9)]
        outs = eng.run_batch(reqs)
        assert len(outs) == len(reqs)
        for (q, _), (rows, _) in zip(reqs, outs):
            want = {tuple(r) for r in eng.run(q)[0].tolist()}
            assert {tuple(r) for r in rows.tolist()} == want, mode
