"""Fault-injection matrix for the concurrent read/write path.

Every leg arms deterministic faults (tests/../src/repro/testing/faults.py)
against instrumented production sites and pins the recovery behavior:

  * mid-flush crash (single + sharded) — the derive-then-commit flush
    leaves the published store consistent; the retried flush produces
    answers bit-identical to the differential oracle;
  * publish crash under the serving runtime — writers commit, readers
    degrade to the last published snapshot with ``stale=True``, and the
    next successful capture catches up;
  * slow shard — a deadlined request reports a miss instead of hanging;
  * shard_map device failure — the stacked path degrades to the per-shard
    dispatch loop with identical answers;
  * ingest part failures — transient ones retry with backoff, persistent
    ones land in the structured report while the stream continues;
  * serving transients — retry-with-jitter inside the request deadline;
  * snapshot retirement — the widened retire window never drops a pinned
    version.
"""
import threading

import numpy as np
import pytest

import jax

from oracle import NaiveKB, query_vars

from repro.core.engine import KnowledgeBase, PAPER_QUERIES
from repro.core.shard import ShardedKB, assert_partitioned
from repro.core.snapshot import SnapshotRegistry
from repro.rdf.generator import generate_lubm
from repro.serving.runtime import ServingRuntime
from repro.testing import faults
from repro.testing.faults import FaultCrash, FaultError, FaultInjector
from test_update import answers_fp

Q1, Q3 = PAPER_QUERIES["Q1"], PAPER_QUERIES["Q3"]


@pytest.fixture(scope="module")
def raw():
    return generate_lubm(1, seed=7)


@pytest.fixture(autouse=True)
def _no_leaked_injector():
    yield
    faults.uninstall()  # a failing test must not poison the next one


# -- harness unit behavior ----------------------------------------------------

def test_injector_windows_and_accounting():
    inj = FaultInjector()
    inj.arm("site.a", exc=FaultError, after=1, times=2)
    inj.fire("site.a")  # hit 1: before the window
    for _ in range(2):  # hits 2, 3: inside
        with pytest.raises(FaultError):
            inj.fire("site.a")
    inj.fire("site.a")  # hit 4: window exhausted
    assert inj.hit_count("site.a") == 4
    assert inj.fired("site.a") == 2
    kinds = [k for _, _, k, _ in inj.log]
    assert kinds == ["hit", "fired", "fired", "hit"]


def test_fire_is_noop_without_installed_injector():
    faults.fire("anything.at.all", n=1)  # must not raise
    with faults.inject() as inj:
        inj.arm("site.b", exc=FaultCrash)
        with pytest.raises(FaultCrash):
            faults.fire("site.b")
    faults.fire("site.b")  # uninstalled again


# -- mid-flush crash ----------------------------------------------------------

def test_mid_flush_crash_single_store_stays_consistent(raw):
    K = KnowledgeBase.build(raw)
    oracle = NaiveKB(raw.onto)
    oracle.insert(raw)
    s, p, o = np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o)
    extra = (s[:64], p[:64], o[:64])
    K.insert(extra, auto_compact=False)  # queued, not yet derived
    oracle.insert(extra)

    with faults.inject() as inj:
        inj.arm("engine.flush_mat", exc=FaultCrash, times=1)
        with pytest.raises(FaultCrash):
            K.view("litemat")  # lazy derivation crashes mid-flush
        assert inj.fired("engine.flush_mat") == 1
        # nothing committed: the retried flush derives the SAME backlog
        # exactly once — answers match the oracle (no drop, no double)
        sel = query_vars(Q3)
        assert answers_fp(K, Q3, select=sel) == oracle.answers(Q3, sel)


def test_mid_flush_crash_sharded_stays_consistent(raw):
    skb = ShardedKB.build(raw, n_shards=2)
    oracle = NaiveKB(raw.onto)
    oracle.insert(raw)
    s, p, o = np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o)
    extra = (s[:64], p[:64], o[:64])
    skb.insert(extra, auto_compact=False)
    oracle.insert(extra)

    with faults.inject() as inj:
        # crash on the SECOND shard's derivation: shard 0's derived rows
        # are staged but must not have been committed
        inj.arm("shard.flush_mat", exc=FaultCrash, after=1, times=1)
        with pytest.raises(FaultCrash):
            skb._flush("litemat")
        assert inj.fired("shard.flush_mat") == 1
    sel = query_vars(Q3)
    assert answers_fp(skb, Q3, select=sel) == oracle.answers(Q3, sel)
    assert_partitioned(skb)


# -- serving runtime degradation ----------------------------------------------

def test_publish_crash_serves_stale_snapshot_then_catches_up(raw):
    K = KnowledgeBase.build(raw)
    rt = ServingRuntime(K, modes=("litemat",), n_workers=1,
                        pin_lock_timeout_s=0.05)
    s, p, o = np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o)
    with rt:
        v0 = rt.serve(Q1).version
        with faults.inject() as inj:
            # fire 1: the writer's publish after insert; fire 2: the first
            # reader's own fresh-capture attempt — both crash, so the
            # reader degrades to the stale published snapshot
            inj.arm("engine.flush_mat", exc=FaultCrash, times=2)
            assert rt.insert((s[:32], p[:32], o[:32]),
                             auto_compact=False)["n_inserted"] == 32
            assert rt.stats["publish_failures"] == 1
            out_stale = rt.serve(Q1)
            assert out_stale.ok and out_stale.stale
            assert out_stale.version == v0
            assert inj.fired("engine.flush_mat") == 2
        out_fresh = rt.serve(Q1)  # fault exhausted: capture succeeds
        assert out_fresh.ok and not out_fresh.stale
        assert out_fresh.version == K.version != v0
        assert rt.stats["stale_served"] == 1


def test_slow_shard_becomes_deadline_miss(raw):
    skb = ShardedKB.build(raw, n_shards=2)
    rt = ServingRuntime(skb, modes=("litemat",), n_workers=1, max_retries=0)
    with rt:
        rt.registry.prewarm([Q1])
        assert rt.serve(Q1).ok  # warm: comfortably under any sane deadline
        with faults.inject() as inj:
            inj.arm("shard.query_shard", exc=None, delay_s=0.25, times=-1)
            out = rt.serve(Q1, deadline_s=0.2)
            assert out.status == "deadline"
            assert inj.fired("shard.query_shard") >= 1
        assert rt.serve(Q1, deadline_s=30.0).ok


def test_serving_transient_retries_with_jitter_inside_deadline(raw):
    K = KnowledgeBase.build(raw)
    rt = ServingRuntime(K, modes=("litemat",), n_workers=1, max_retries=3,
                        retry_backoff_s=0.001)
    with rt:
        rt.registry.prewarm([Q1])
        with faults.inject() as inj:
            inj.arm("serving.execute", exc=FaultError, times=2)
            out = rt.serve(Q1, deadline_s=30.0)
            assert out.ok and out.retries == 2
        assert rt.stats["retries"] == 2
        with faults.inject() as inj:
            inj.arm("serving.execute", exc=FaultError, times=-1)
            out = rt.serve(Q1)  # budget exhausted -> reported, not raised
            assert out.status == "error" and "FaultError" in out.error


def test_admission_queue_sheds_past_capacity(raw):
    K = KnowledgeBase.build(raw)
    rt = ServingRuntime(K, modes=("litemat",), n_workers=1, max_queue=2)
    with rt:
        rt.registry.prewarm([Q1])
        with faults.inject() as inj:
            # park the worker inside its first request so the queue backs up
            inj.arm("serving.execute", exc=None, delay_s=0.3, times=1)
            futs = [rt.submit(Q1) for _ in range(8)]
            outs = [f.result() for f in futs]
        statuses = [o.status for o in outs]
        assert statuses.count("shed") >= 5  # capacity 2 + 1 in flight
        assert all(o.ok for o in outs if o.status == "ok")
        assert rt.stats["shed"] == statuses.count("shed")


# -- shard_map device failure -------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs multiple devices (forced-8 CI leg)")
def test_shard_map_failure_falls_back_to_dispatch_loop(raw):
    skb = ShardedKB.build(raw, n_shards=min(jax.device_count(), 4))
    eng = skb.engine("litemat")
    assert eng._shard_map_on()
    want, sel = skb.query(Q3)
    with faults.inject() as inj:
        inj.arm("shard.shard_map", exc=FaultError, times=1)
        rows, sel2 = skb.query(Q3)
        assert inj.fired("shard.shard_map") == 1
    assert eng.cache_stats["shard_map_faults"] == 1
    assert eng.cache_stats["loop_runs"] >= 1
    assert sel2 == sel and np.array_equal(np.asarray(rows), np.asarray(want))


# -- ingest fault tolerance ---------------------------------------------------

def _parts(raw, n_parts=4, rows_per=96):
    s, p, o = np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o)
    return [(s[i * rows_per:(i + 1) * rows_per],
             p[i * rows_per:(i + 1) * rows_per],
             o[i * rows_per:(i + 1) * rows_per]) for i in range(n_parts)]


def test_ingest_retries_transient_part_failures(raw):
    from repro.core.tbox import build_tbox

    tbox = build_tbox(raw.onto)
    parts = _parts(raw)
    with faults.inject() as inj:
        # part 1's encode fails twice, then succeeds on the third attempt
        inj.arm("shard.ingest_encode", exc=FaultError, after=1, times=2)
        skb = ShardedKB.ingest(parts, tbox=tbox, n_shards=2,
                               max_part_retries=3, backoff_s=0.001)
    rep = skb.ingest_report
    assert rep.ok and rep.n_retries == 2
    assert [p["attempts"] for p in rep.parts] == [1, 3, 1, 1]
    assert skb.version == len(parts)
    assert rep.n_rows == sum(p[0].shape[0] for p in parts)
    assert_partitioned(skb)


def test_ingest_reports_persistent_failure_and_continues(raw):
    from repro.core.tbox import build_tbox

    tbox = build_tbox(raw.onto)
    parts = _parts(raw)
    with faults.inject() as inj:
        # part 2 fails on every attempt (hits 3..5: first attempt + retries)
        inj.arm("shard.ingest_encode", exc=FaultError, after=2, times=3)
        skb = ShardedKB.ingest(parts, tbox=tbox, n_shards=2,
                               max_part_retries=2, backoff_s=0.001)
    rep = skb.ingest_report
    assert not rep.ok
    assert [p["part"] for p in rep.failed] == [2]
    assert rep.failed[0]["attempts"] == 3 and "FaultError" in \
        rep.failed[0]["error"]
    # the stream continued past the bad part; the store is consistent at
    # the version the successful parts published
    assert [p["ok"] for p in rep.parts] == [True, True, False, True]
    assert skb.version == 3
    assert_partitioned(skb)


def test_ingest_hard_crash_is_not_retried(raw):
    from repro.core.tbox import build_tbox

    tbox = build_tbox(raw.onto)
    parts = _parts(raw, n_parts=2)
    with faults.inject() as inj:
        inj.arm("shard.ingest_encode", exc=FaultCrash, after=1, times=-1)
        skb = ShardedKB.ingest(parts, tbox=tbox, n_shards=2,
                               max_part_retries=5, backoff_s=0.001)
        assert inj.fired("shard.ingest_encode") == 1  # no retry attempts
    rep = skb.ingest_report
    assert [p["ok"] for p in rep.parts] == [True, False]
    assert rep.parts[1]["attempts"] == 1


# -- snapshot-retire race -----------------------------------------------------

def test_retire_window_never_drops_a_pinned_version(raw):
    K = KnowledgeBase.build(raw)
    reg = SnapshotRegistry(K, modes=("litemat",))
    s, p, o = np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o)
    reg.publish()
    errors = []

    with faults.inject() as inj:
        inj.arm("snapshot.retire", exc=None, delay_s=0.02,
                times=-1)  # widen the race window

        def reader():
            try:
                for _ in range(6):
                    with reg.pin() as pin:
                        assert pin.version in reg.live_versions()
                        assert len(pin.answers(Q1)) > 0
            except Exception as e:  # noqa: BLE001 — surfaced below
                errors.append(e)

        threads = [threading.Thread(target=reader) for _ in range(3)]
        for t in threads:
            t.start()
        for i in range(4):  # writer churns versions -> publish + retire
            K.delete((s[i * 16:(i + 1) * 16], p[i * 16:(i + 1) * 16],
                      o[i * 16:(i + 1) * 16]), auto_compact=False)
            reg.publish()
        for t in threads:
            t.join()
        assert inj.hit_count("snapshot.retire") > 0

    assert not errors
    # quiesced: only the published version remains
    reg.retire()
    assert reg.live_versions() == [reg.published.version]
    assert reg.pinned_versions() == []
