"""TBox encoder properties: the heart of the paper.

The invariant (paper §III.A): for any two entities A, B in the classified
hierarchy, B is a (DAG-)descendant-or-self of A  <=>  idB falls in A's
primary interval or one of A's spill intervals.  Hypothesis generates random
DAG taxonomies (including multiple inheritance and equivalence cycles).
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.hierarchy import build_taxonomy
from repro.core.tbox import (
    Ontology, build_tbox, encode_hierarchy, encode_hierarchy_parallel,
)


@st.composite
def random_dag(draw):
    n = draw(st.integers(2, 40))
    names = [f"N{i}" for i in range(n)]
    edges = []
    for i in range(1, n):
        n_par = draw(st.integers(1, min(3, i)))
        parents = draw(
            st.lists(st.integers(0, i - 1), min_size=n_par, max_size=n_par, unique=True)
        )
        for p in parents:
            edges.append((names[i], names[p]))
    # occasionally add an equivalence cycle
    if n > 4 and draw(st.booleans()):
        edges.append((names[1], names[2]))
        edges.append((names[2], names[1]))
    return names, edges


@given(random_dag())
@settings(max_examples=40, deadline=None)
def test_interval_subsumption_matches_dag(dag):
    names, edges = dag
    tax = build_taxonomy(names, edges)
    enc = encode_hierarchy(tax)

    for a in range(tax.n):
        truth = tax.dag_descendants(a) | {a}
        got_ids = set(enc.subsumees(tax.names[a]))
        got = {enc._id_to_node[i] for i in got_ids}
        assert got == truth, (
            f"node {tax.names[a]}: interval gives {sorted(got)}, DAG says {sorted(truth)}"
        )


@given(random_dag())
@settings(max_examples=20, deadline=None)
def test_parallel_encoder_matches_host(dag):
    names, edges = dag
    tax = build_taxonomy(names, edges)
    e1 = encode_hierarchy(tax)
    e2 = encode_hierarchy_parallel(tax)
    assert e1.total_bits == e2.total_bits
    assert np.array_equal(e1.ids, e2.ids)
    assert np.array_equal(e1.used_bits, e2.used_bits)


def test_equivalence_cycle_merges():
    tax = build_taxonomy(["A", "B", "C"], [("A", "B"), ("B", "A"), ("C", "A")])
    enc = encode_hierarchy(tax)
    assert enc.id_of("A") == enc.id_of("B")  # merged class
    assert enc.id_of("C") in set(enc.subsumees("B"))


def test_prefix_property_paper_example():
    """LUBM-style: AssociateProfessor shares Person's prefix (paper Table I)."""
    from repro.rdf.vocab import lubm_ontology

    tb = build_tbox(lubm_ontology())
    enc = tb.concepts
    person = enc.id_of("Person")
    assoc = enc.id_of("AssociateProfessor")
    (lo, hi), _ = enc.interval_of("Person")
    assert lo <= assoc < hi
    # siblings at the top level do not overlap
    (olo, ohi), _ = enc.interval_of("Organization")
    assert ohi <= lo or hi <= olo


def test_deep_hierarchy_goes_wide():
    names = [f"C{i}" for i in range(75)]
    edges = [(f"C{i+1}", f"C{i}") for i in range(74)]
    # give every node several children so each level needs >= 2 bits
    extra = [(f"C{i}_x{j}", f"C{i}") for i in range(74) for j in range(2)]
    tax = build_taxonomy(names + [e[0] for e in extra], edges + extra)
    enc = encode_hierarchy(tax)
    assert enc.total_bits > 62
    assert enc.wide_words >= 3
    # wide interval check still works via bigints
    subs = enc.subsumees("C70")
    assert enc.id_of("C71") in subs


def test_domain_range_tables():
    onto = Ontology(
        concepts=["A", "B"], properties=["p", "q"],
        subclass=[("B", "A")], subprop=[("q", "p")],
        domain={"p": ["A"]}, range_={"p": ["B"]},
    )
    tb = build_tbox(onto)
    i = list(tb.dr_prop_ids).index(tb.property_id("p"))
    assert tb.domain_table[i, 0] == tb.concept_id("A")
    assert tb.range_table[i, 0] == tb.concept_id("B")
