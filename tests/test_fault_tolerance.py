"""Checkpoint/restart, preemption, elastic restore — and the concurrent
read/write stress test: threaded readers pinned against a mutating store
must match the differential oracle AT THEIR PINNED VERSION, bit-identical.
"""
import threading
import time

import numpy as np

import jax
import jax.numpy as jnp

from oracle import NaiveKB, query_vars

from repro.core.engine import KnowledgeBase, PAPER_QUERIES
from repro.core.shard import ShardedKB
from repro.core.snapshot import SnapshotRegistry
from repro.distributed.checkpoint import CheckpointManager
from repro.rdf.generator import generate_lubm
from repro.serving.runtime import ServingRuntime
from repro.utils import pair64


def _toy_state(seed=0):
    k = jax.random.key(seed)
    return {
        "w": jax.random.normal(k, (8, 4)),
        "nested": {"b": jnp.arange(6, dtype=jnp.float32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = _toy_state()
    mgr.save(10, state, extra={"next_step": 10})
    restored, manifest = mgr.restore(state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["extra"]["next_step"] == 10


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _toy_state(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_integrity_detection(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    path = mgr.save(5, _toy_state())
    # corrupt the arrays file
    f = path / "arrays.npz"
    data = bytearray(f.read_bytes())
    data[len(data) // 2] ^= 0xFF
    f.write_bytes(bytes(data))
    try:
        mgr.restore(_toy_state())
        raise AssertionError("corruption went undetected")
    except (IOError, ValueError, Exception):  # zlib/crc or our hash check
        pass


def test_train_resume_bit_exact(tmp_path):
    """Kill-and-resume produces the same state as an uninterrupted run."""
    from repro.configs.registry import get_arch
    from repro.data.tokens import TokenStream
    from repro.models import lm as lm_lib
    from repro.train.loop import TrainLoop
    from repro.train.optimizer import init_opt_state

    cfg = get_arch("olmo-1b").reduced_config()
    stream = TokenStream(cfg.vocab, 2, 16, seed=5)
    step_fn = jax.jit(lm_lib.make_train_step(cfg))

    def fresh():
        p = lm_lib.init_params(jax.random.key(0), cfg)
        return p, init_opt_state(p)

    # uninterrupted 6 steps
    p, o = fresh()
    loop_a = TrainLoop(step_fn, stream.batch_at, CheckpointManager(tmp_path / "a"),
                       ckpt_every=100, log_every=1000)
    pa, oa, _, _ = loop_a.run(p, o, 6, start_step=0)

    # interrupted after 3 (simulated preemption), then resumed
    p, o = fresh()
    mgr = CheckpointManager(tmp_path / "b")
    loop_b = TrainLoop(step_fn, stream.batch_at, mgr, ckpt_every=3, log_every=1000)
    pb, ob, s, _ = loop_b.run(p, o, 3, start_step=0)
    assert mgr.latest_step() is not None
    loop_c = TrainLoop(step_fn, stream.batch_at, mgr, ckpt_every=100, log_every=1000)
    pc, oc, s2, _ = loop_c.run(p, o, 6)  # restores from step 3
    assert s2 == 6
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Threaded mixed workload: pinned readers vs a mutating writer
# ---------------------------------------------------------------------------

QUERIES = {n: PAPER_QUERIES[n] for n in ("Q1", "Q2", "Q3", "Q4")}
SEL = {n: query_vars(q) for n, q in QUERIES.items()}


def _fp_set(kb, rows) -> set:
    """Result rows -> fingerprint space (the oracle's identity)."""
    rows = np.asarray(rows)
    if rows.size == 0:
        return set()
    ids = jnp.asarray(rows.reshape(-1).astype(np.int32))
    hi, lo, hit = kb.kb.table.extract_fp(ids)
    fps = pair64.combine_np(np.asarray(hi), np.asarray(lo))
    fps = np.where(np.asarray(hit), fps, rows.reshape(-1))
    return {tuple(r) for r in fps.reshape(rows.shape).tolist()}


def _record(kb, oracle, expected) -> None:
    """Write-lock-held: oracle answers for the CURRENT version.

    The writer calls this before releasing the lock after every mutation,
    so any version a reader can possibly pin (published fast path, fresh
    capture — both see only post-critical-section versions) already has
    its expected answer set.
    """
    expected[kb.version] = {
        n: oracle.answers(q, SEL[n]) for n, q in QUERIES.items()}


def _writer_script(raw):
    s, p, o = np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o)

    def tr(a, b):
        return (s[a:b], p[a:b], o[a:b])

    return [
        ("delete", tr(0, 100)),
        ("insert", tr(0, 50)),  # re-insert half the deleted rows
        ("compact", None),
        ("delete", tr(300, 360)),
        ("insert", tr(1000, 1040)),
        ("compact", None),
    ]


def _apply(kb, oracle, op, payload):
    if op == "insert":
        kb.insert(payload, auto_compact=False)
        oracle.insert(payload)
    elif op == "delete":
        kb.delete(payload, auto_compact=False)
        oracle.delete(payload)
    else:
        kb.compact()
        oracle.compact()


def test_threaded_readers_match_oracle_at_pinned_version():
    """N pinned readers racing 1 writer: every answer exact at its version.

    The writer applies insert/delete/compact to the store AND the NaiveKB
    oracle inside one write-lock critical section, recording the oracle's
    answers keyed by the new version before releasing; readers concurrently
    pin snapshots (Q1–Q4 x litemat/rewrite round-robin) and every answer
    set must equal the oracle's at the READER'S pinned version — the MVCC
    contract under real thread interleaving, including stale degraded pins.
    """
    raw = generate_lubm(1, seed=7)
    K = KnowledgeBase.build(raw)
    oracle = NaiveKB(raw.onto)
    oracle.insert(raw)
    reg = SnapshotRegistry(K, modes=("litemat", "rewrite"),
                           lock_timeout_s=0.05)
    expected: dict = {}
    with K.write_lock:
        _record(K, oracle, expected)
    reg.publish()
    reg.prewarm(list(QUERIES.values()))

    failures: list = []
    pairs = [(n, m) for n in QUERIES for m in ("litemat", "rewrite")]

    def reader(rid: int, iters: int = 6):
        try:
            for i in range(iters):
                name, mode = pairs[(rid + 3 * i) % len(pairs)]
                with reg.pin() as pin:
                    rows, _ = pin.query(QUERIES[name], select=SEL[name],
                                        mode=mode)
                    got = _fp_set(K, rows)
                    want = expected[pin.version][name]
                    if got != want:
                        failures.append(
                            (rid, i, name, mode, pin.version, pin.stale,
                             len(got), len(want)))
        except Exception as e:  # noqa: BLE001 — surfaced by the assert
            failures.append((rid, "exception", repr(e)))

    def writer():
        try:
            for op, payload in _writer_script(raw):
                with K.write_lock:
                    _apply(K, oracle, op, payload)
                    _record(K, oracle, expected)
                reg.publish()
        except Exception as e:  # noqa: BLE001
            failures.append(("writer", "exception", repr(e)))

    threads = [threading.Thread(target=reader, args=(r,)) for r in range(3)]
    threads.append(threading.Thread(target=writer))
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert not failures, failures[:5]
    assert len(expected) == 7  # v0 + six writer ops all recorded
    # quiesced: one final fresh pin sees the final version exactly
    with reg.pin() as pin:
        assert pin.version == K.version and not pin.stale
        rows, _ = pin.query(QUERIES["Q3"], select=SEL["Q3"])
        assert _fp_set(K, rows) == expected[K.version]["Q3"]


def test_sharded_runtime_mixed_workload_matches_oracle():
    """The same contract through the ServingRuntime over a ShardedKB.

    Requests stream through the bounded admission queue while a writer
    thread mutates the shards; outcomes are compared post-hoc against the
    oracle at each outcome's reported version.  At this baseline load
    nothing sheds and nothing misses its (generous) deadline.
    """
    raw = generate_lubm(1, seed=7)
    skb = ShardedKB.build(raw, n_shards=2)
    oracle = NaiveKB(raw.onto)
    oracle.insert(raw)
    rt = ServingRuntime(skb, modes=("litemat",), n_workers=2, max_queue=64,
                        pin_lock_timeout_s=0.1)
    expected: dict = {}
    with skb.write_lock:
        _record(skb, oracle, expected)
    with rt:
        rt.registry.prewarm(list(QUERIES.values()))
        done = threading.Event()

        def writer():
            try:
                for op, payload in _writer_script(raw)[:4]:
                    with skb.write_lock:
                        _apply(skb, oracle, op, payload)
                        _record(skb, oracle, expected)
                    rt.registry.publish()
            finally:
                done.set()

        w = threading.Thread(target=writer)
        w.start()
        names, futs = [], []
        i = 0
        while not done.is_set() or i < 8:  # keep reading past the last write
            name = list(QUERIES)[i % len(QUERIES)]
            names.append(name)
            futs.append(rt.submit(QUERIES[name], select=SEL[name],
                                  deadline_s=60.0))
            i += 1
            if i >= 48:
                break
            time.sleep(0.01)  # pace submissions across the writer's ops
        outs = [f.result() for f in futs]
        w.join()

    assert all(o.ok for o in outs), [
        (o.status, o.error) for o in outs if not o.ok][:3]
    assert rt.stats["shed"] == 0 and rt.stats["deadline"] == 0
    for name, out in zip(names, outs):
        rows = np.asarray(sorted(out.answers)) if out.answers else \
            np.zeros((0, len(SEL[name])), np.int32)
        assert _fp_set(skb, rows) == expected[out.version][name], (
            name, out.version, out.stale)
    assert len(expected) == 5  # v0 + four writer ops
