"""Checkpoint/restart, preemption, elastic restore."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.distributed.checkpoint import CheckpointManager


def _toy_state(seed=0):
    k = jax.random.key(seed)
    return {
        "w": jax.random.normal(k, (8, 4)),
        "nested": {"b": jnp.arange(6, dtype=jnp.float32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = _toy_state()
    mgr.save(10, state, extra={"next_step": 10})
    restored, manifest = mgr.restore(state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["extra"]["next_step"] == 10


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _toy_state(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_integrity_detection(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    path = mgr.save(5, _toy_state())
    # corrupt the arrays file
    f = path / "arrays.npz"
    data = bytearray(f.read_bytes())
    data[len(data) // 2] ^= 0xFF
    f.write_bytes(bytes(data))
    try:
        mgr.restore(_toy_state())
        raise AssertionError("corruption went undetected")
    except (IOError, ValueError, Exception):  # zlib/crc or our hash check
        pass


def test_train_resume_bit_exact(tmp_path):
    """Kill-and-resume produces the same state as an uninterrupted run."""
    from repro.configs.registry import get_arch
    from repro.data.tokens import TokenStream
    from repro.models import lm as lm_lib
    from repro.train.loop import TrainLoop
    from repro.train.optimizer import init_opt_state

    cfg = get_arch("olmo-1b").reduced_config()
    stream = TokenStream(cfg.vocab, 2, 16, seed=5)
    step_fn = jax.jit(lm_lib.make_train_step(cfg))

    def fresh():
        p = lm_lib.init_params(jax.random.key(0), cfg)
        return p, init_opt_state(p)

    # uninterrupted 6 steps
    p, o = fresh()
    loop_a = TrainLoop(step_fn, stream.batch_at, CheckpointManager(tmp_path / "a"),
                       ckpt_every=100, log_every=1000)
    pa, oa, _, _ = loop_a.run(p, o, 6, start_step=0)

    # interrupted after 3 (simulated preemption), then resumed
    p, o = fresh()
    mgr = CheckpointManager(tmp_path / "b")
    loop_b = TrainLoop(step_fn, stream.batch_at, mgr, ckpt_every=3, log_every=1000)
    pb, ob, s, _ = loop_b.run(p, o, 3, start_step=0)
    assert mgr.latest_step() is not None
    loop_c = TrainLoop(step_fn, stream.batch_at, mgr, ckpt_every=100, log_every=1000)
    pc, oc, s2, _ = loop_c.run(p, o, 6)  # restores from step 3
    assert s2 == 6
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)
