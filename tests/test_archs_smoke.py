"""Per-architecture smoke tests: reduced configs, one real step on CPU,
asserting output shapes and finiteness (the full configs are exercised via
the dry-run's abstract lowering only)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_arch

LM_ARCHS = [a for a, m in ARCHS.items() if m.FAMILY == "lm"]
GNN_ARCHS = [a for a, m in ARCHS.items() if m.FAMILY == "gnn"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_reduced_train_and_decode(arch):
    from repro.models import lm as lm_lib
    from repro.train.optimizer import init_opt_state

    cfg = get_arch(arch).reduced_config()
    key = jax.random.key(0)
    params = lm_lib.init_params(key, cfg)
    B, S = 2, 32
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tok, "targets": jnp.roll(tok, -1, 1),
             "mask": jnp.ones((B, S), jnp.float32)}
    step = jax.jit(lm_lib.make_train_step(cfg))
    params2, opt2, metrics = step(params, init_opt_state(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # decode one token against a prefilled cache
    prefill = jax.jit(lm_lib.make_prefill_step(cfg, max_seq=S + 4))
    logits, cache = prefill(params, tok)
    assert logits.shape == (B, 1, cfg.vocab)
    decode = jax.jit(lm_lib.make_decode_step(cfg))
    lg, cache2 = decode(params, cache, tok[:, :1], jnp.int32(S))
    assert lg.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all()


def test_lm_prefill_decode_consistency():
    """Decoding the next position after prefill must match a fresh forward."""
    from repro.models import lm as lm_lib

    cfg = get_arch("olmo-1b").reduced_config()
    key = jax.random.key(1)
    params = lm_lib.init_params(key, cfg)
    B, S = 2, 16
    tok = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    prefill = jax.jit(lm_lib.make_prefill_step(cfg, max_seq=S + 4))
    _, cache = prefill(params, tok[:, :S])
    decode = jax.jit(lm_lib.make_decode_step(cfg))
    lg_dec, _ = decode(params, cache, tok[:, S:S + 1], jnp.int32(S))
    _, full_cache = prefill(params, tok)  # includes position S
    x_full, _ = lm_lib.forward(params, tok, cfg)
    lg_full = lm_lib.logits_fn(x_full[:, S:S + 1], params["embed"])
    np.testing.assert_allclose(
        np.asarray(lg_dec), np.asarray(lg_full), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_reduced_step(arch):
    from repro.data.graphs import make_cora_like, make_molecules
    from repro.launch.cells import make_gnn_train_step, _GNN_MODELS

    mod = get_arch(arch)
    model = _GNN_MODELS[mod.MODEL]
    if mod.MODEL in ("schnet", "equiformer"):
        g = make_molecules(n_graphs=4, nodes_per=8, edges_per=16)
        task = "reg"
        cfg = mod.reduced_config()
    else:
        g = make_cora_like(n_nodes=120, n_edges=480, d_feat=64, seed=2)
        task = "cls"
        cfg = mod.reduced_config(d_feat=64, n_classes=7)
    gj = {k: jnp.asarray(v) for k, v in g.items() if k != "n_graphs"}
    params = model.init_params(jax.random.key(0), cfg)
    step = jax.jit(make_gnn_train_step(mod.MODEL, cfg, task))
    params2, loss = step(params, gj)
    assert np.isfinite(float(loss))
    # a second step must change the loss (gradients flow)
    _, loss2 = step(params2, gj)
    assert float(loss2) != float(loss)


def test_gnn_training_learns():
    """gat on a learnable synthetic cora: loss decreases materially."""
    from repro.data.graphs import make_cora_like
    from repro.launch.cells import make_gnn_train_step
    from repro.models.gnn import gat

    g = make_cora_like(n_nodes=150, n_edges=600, d_feat=32, seed=3)
    gj = {k: jnp.asarray(v) for k, v in g.items()}
    cfg = gat.GATConfig(d_in=32, d_hidden=8, n_heads=4)
    params = gat.init_params(jax.random.key(0), cfg)
    step = jax.jit(make_gnn_train_step("gat", cfg, "cls", lr=0.5))
    losses = []
    for _ in range(100):
        params, loss = step(params, gj)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses[::20]
