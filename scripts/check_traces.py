#!/usr/bin/env python
"""Validate obs export files: trace exports AND metrics snapshots.

CI's obs smoke leg runs the serving bench with ``REPRO_TRACE_EXPORT`` /
``REPRO_METRICS_EXPORT`` set, the distributed leg exports per-process
mergeable snapshots plus the aggregated fleet snapshot, and this script
holds every resulting file to its contract:

  * trace files (``{"traces": [...]}``) — ``repro.obs.export.TRACE_SCHEMA``
    plus the structural invariants (exactly one root span per trace, no
    dangling parent_ids, ordered [t0, t1] windows);
  * metrics snapshots (``{"schema": "repro.metrics.snapshot/1", ...}`` or
    the aggregated ``repro.metrics.fleet/1`` form) —
    ``repro.obs.export.validate_metrics_snapshot``: schema walk, integer
    bucket indexes, bucket counts reconciling with totals, ordered
    min/max envelopes.

File kind is auto-detected from the document shape.  Any violation
prints the offending trace/span/entry and exits 1, failing the job.

Usage:
    PYTHONPATH=src python scripts/check_traces.py traces.json metrics.json
    PYTHONPATH=src python scripts/check_traces.py --min-traces 10 traces.json

Exit codes: 0 all files valid, 1 invalid content / unreadable file /
fewer traces than ``--min-traces`` (a silently-empty export must not
pass the smoke leg).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.export import validate_metrics_snapshot, validate_trace


def check_metrics(path: str, doc: dict) -> int:
    """Validate one metrics snapshot document; returns error count."""
    errors = validate_metrics_snapshot(doc)
    for err in errors:
        print(f"{path}: {err}")
    kind = doc.get("schema", "?")
    n_hists = len(doc.get("histograms", ()))
    print(f"# {path}: {kind} snapshot, {n_hists} histograms, "
          f"{len(errors)} errors")
    return len(errors)


def check_file(path: str, min_traces: int) -> int:
    """Validate one export file; returns the number of errors printed."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable ({e})")
        return 1
    if isinstance(doc, dict) and isinstance(doc.get("schema"), str) \
            and doc["schema"].startswith("repro.metrics."):
        return check_metrics(path, doc)
    traces = doc.get("traces") if isinstance(doc, dict) else None
    if not isinstance(traces, list):
        print(f"{path}: neither a 'traces' array nor a metrics snapshot")
        return 1
    n_errors = 0
    for i, trace in enumerate(traces):
        errors = validate_trace(trace)
        for err in errors:
            print(f"{path}[{i}]: {err}")
        n_errors += len(errors)
    if len(traces) < min_traces:
        print(f"{path}: only {len(traces)} traces, expected >= {min_traces}")
        n_errors += 1
    dropped = doc.get("dropped", 0)
    print(f"# {path}: {len(traces)} traces checked, "
          f"{n_errors} errors, {dropped} dropped by the ring")
    return n_errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+")
    ap.add_argument("--min-traces", type=int, default=1,
                    help="fail when a trace file holds fewer than this")
    args = ap.parse_args(argv)
    total = sum(check_file(p, args.min_traces) for p in args.files)
    return 1 if total else 0


if __name__ == "__main__":
    sys.exit(main())
