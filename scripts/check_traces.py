#!/usr/bin/env python
"""Validate every trace in an export file against the obs trace schema.

CI's obs smoke leg runs the serving bench with ``REPRO_TRACE_EXPORT`` set,
then holds the resulting file to the contract in
``repro.obs.export.TRACE_SCHEMA`` plus the structural invariants
(exactly one root span per trace, no dangling parent_ids, ordered
[t0, t1] windows).  Any violation prints the offending trace/span and
exits 1, failing the job.

Usage:
    PYTHONPATH=src python scripts/check_traces.py traces.json [more...]
    PYTHONPATH=src python scripts/check_traces.py --min-traces 10 traces.json

Exit codes: 0 all traces valid, 1 invalid trace / unreadable file /
fewer traces than ``--min-traces`` (a silently-empty export must not
pass the smoke leg).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.export import validate_trace


def check_file(path: str, min_traces: int) -> int:
    """Validate one export file; returns the number of errors printed."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable ({e})")
        return 1
    traces = doc.get("traces")
    if not isinstance(traces, list):
        print(f"{path}: no 'traces' array")
        return 1
    n_errors = 0
    for i, trace in enumerate(traces):
        errors = validate_trace(trace)
        for err in errors:
            print(f"{path}[{i}]: {err}")
        n_errors += len(errors)
    if len(traces) < min_traces:
        print(f"{path}: only {len(traces)} traces, expected >= {min_traces}")
        n_errors += 1
    dropped = doc.get("dropped", 0)
    print(f"# {path}: {len(traces)} traces checked, "
          f"{n_errors} errors, {dropped} dropped by the ring")
    return n_errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+")
    ap.add_argument("--min-traces", type=int, default=1,
                    help="fail when a file holds fewer traces than this")
    args = ap.parse_args(argv)
    total = sum(check_file(p, args.min_traces) for p in args.files)
    return 1 if total else 0


if __name__ == "__main__":
    sys.exit(main())
