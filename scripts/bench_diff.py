#!/usr/bin/env python
"""Diff fresh BENCH_*.json artifacts against the previous commit's.

CI's bench job regenerates BENCH_queries.json / BENCH_updates.json in the
working tree; this script compares every time-like row against the version
committed at a baseline git ref (the previous run's artifact) and FAILS the
job when a metric regressed by more than ``--tolerance`` (default 20%).

Guards against CPU-runner noise:

  * rows below ``--min-us`` (default 50ms) are informational only — a 3ms
    kernel dispatch jitters far beyond 20% on shared runners,
  * rows whose ``us_per_call`` is 0 (pure pass/fail or ratio rows, e.g.
    ``updates/warmup_flatness``) are compared on their ``passed`` flag
    instead: a True -> False flip is always a failure.

Usage:
    python scripts/bench_diff.py [--baseline-ref HEAD~1] [--tolerance 0.2]
                                 [--min-us 50000] [files...]

Exit codes: 0 ok / baseline missing (first run), 1 regression found.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys

DEFAULT_FILES = ("BENCH_queries.json", "BENCH_updates.json")


def _load_current(path: str) -> dict | None:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def _load_baseline(ref: str, path: str) -> dict | None:
    try:
        blob = subprocess.run(
            ["git", "show", f"{ref}:{path}"],
            capture_output=True, check=True).stdout
        return json.loads(blob)
    except (subprocess.CalledProcessError, json.JSONDecodeError, OSError):
        return None


def _rows_by_name(artifact: dict) -> dict:
    return {r["name"]: r for r in artifact.get("rows", [])
            if isinstance(r, dict) and "name" in r}


def diff_artifact(cur: dict, base: dict, tolerance: float, min_us: float):
    """-> (regressions, improvements, notes) as printable strings."""
    regressions, improvements, notes = [], [], []
    cur_rows, base_rows = _rows_by_name(cur), _rows_by_name(base)
    for name, row in sorted(cur_rows.items()):
        prev = base_rows.get(name)
        if prev is None:
            notes.append(f"  new row: {name}")
            continue
        c_us, b_us = float(row.get("us_per_call", 0)), float(
            prev.get("us_per_call", 0))
        if c_us == 0 or b_us == 0:
            # pass/fail or ratio rows: a flag flip is the regression signal
            if prev.get("passed") is True and row.get("passed") is False:
                regressions.append(
                    f"  {name}: passed True -> False ({row})")
            continue
        rel = c_us / b_us - 1.0
        line = (f"  {name}: {b_us / 1e3:.1f}ms -> {c_us / 1e3:.1f}ms "
                f"({rel:+.0%})")
        if rel > tolerance:
            if max(c_us, b_us) < min_us:
                notes.append(line + "  [below noise floor, ignored]")
            else:
                regressions.append(line)
        elif rel < -tolerance and max(c_us, b_us) >= min_us:
            improvements.append(line)
    return regressions, improvements, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", default=list(DEFAULT_FILES))
    ap.add_argument("--baseline-ref", default="HEAD~1",
                    help="git ref holding the previous artifact")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="relative slowdown that fails the job (0.2 = +20%%)")
    ap.add_argument("--min-us", type=float, default=50_000,
                    help="noise floor: rows faster than this never fail")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0")
    args = ap.parse_args(argv)
    files = args.files or list(DEFAULT_FILES)

    failed = False
    for path in files:
        cur = _load_current(path)
        if cur is None:
            print(f"# {path}: no current artifact (bench not run?) — skipped")
            continue
        base = _load_baseline(args.baseline_ref, path)
        if base is None:
            print(f"# {path}: no baseline at {args.baseline_ref} — skipped "
                  "(first run or shallow clone)")
            continue
        scale = ("bench_universities", "n_base_triples")
        if any(cur.get(k) != base.get(k) for k in scale):
            print(f"# {path}: benchmark scale changed "
                  f"({ {k: (base.get(k), cur.get(k)) for k in scale} }) — "
                  "timings not comparable, skipped")
            continue
        reg, imp, notes = diff_artifact(cur, base, args.tolerance,
                                        args.min_us)
        print(f"# {path} vs {args.baseline_ref} "
              f"(tolerance +{args.tolerance:.0%}, floor {args.min_us / 1e3:.0f}ms)")
        for line in notes:
            print(line)
        if imp:
            print(" improvements:")
            for line in imp:
                print(line)
        if reg:
            print(" REGRESSIONS:")
            for line in reg:
                print(line)
            failed = True
        if not reg and not imp:
            print("  no significant changes")

    if failed and not args.warn_only:
        print("bench_diff: FAILED (see REGRESSIONS above)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
