#!/usr/bin/env python
"""Diff fresh BENCH_*.json artifacts against the previous run's.

CI's bench job regenerates BENCH_queries.json / BENCH_updates.json in the
working tree; this script compares every time-like row against a baseline
artifact and FAILS the job when a metric regressed by more than
``--tolerance`` (default 20%).

Baseline resolution order (same-hardware beats same-repo):

  1. ``--baseline-dir DIR`` — a directory holding the PREVIOUS CI run's
     uploaded bench artifact (the CI workflow downloads it with ``gh run
     download`` before this script runs).  Those timings came from the
     same runner class as the fresh ones, so the 20% gate is meaningful
     all the way down to the noise floor — unlike the committed artifact,
     which may have been regenerated on a dev machine with very different
     single-core performance.  Searched recursively (``gh run download``
     nests files under per-artifact directories).
  2. ``--baseline-ref REF`` (default HEAD~1) — the artifact committed at a
     git ref.  Cross-hardware fallback for local use and for the first CI
     run after this scheme lands (no uploaded artifact exists yet).

Guards against CPU-runner noise:

  * rows below ``--min-us`` (default 50ms) are informational only — a 3ms
    kernel dispatch jitters far beyond 20% on shared runners,
  * rows whose ``us_per_call`` is 0 (pure pass/fail or ratio rows, e.g.
    ``updates/warmup_flatness`` or ``serving/batched_speedup``, the
    >=3x micro-batched throughput flag) are compared on their ``passed``
    flag instead: a True -> False flip is always a failure.

Rows carrying a ``gate_max_pct`` field (e.g. ``serving/obs_overhead``,
the <3% tracing-overhead budget) are ABSOLUTE gates: they fail on their
own ``passed`` flag with no baseline needed — the bench computed the
overhead against an untraced run in the same process, so cross-run
hardware noise does not apply.

Usage:
    python scripts/bench_diff.py [--baseline-dir prev-bench]
                                 [--baseline-ref HEAD~1] [--tolerance 0.2]
                                 [--min-us 50000] [files...]

Exit codes: 0 ok / baseline missing (first run), 1 regression found.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

DEFAULT_FILES = ("BENCH_queries.json", "BENCH_updates.json",
                 "BENCH_serving.json")


def _load_current(path: str) -> dict | None:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def _load_baseline_dir(base_dir: str, path: str) -> tuple[dict, str] | None:
    """Find ``basename(path)`` anywhere under ``base_dir`` and load it."""
    if not base_dir or not os.path.isdir(base_dir):
        return None
    want = os.path.basename(path)
    for root, _dirs, files in sorted(os.walk(base_dir)):
        if want in files:
            full = os.path.join(root, want)
            try:
                with open(full) as f:
                    return json.load(f), full
            except (OSError, json.JSONDecodeError):
                return None
    return None


def _load_baseline(ref: str, path: str) -> dict | None:
    try:
        blob = subprocess.run(
            ["git", "show", f"{ref}:{path}"],
            capture_output=True, check=True).stdout
        return json.loads(blob)
    except (subprocess.CalledProcessError, json.JSONDecodeError, OSError):
        return None


def _rows_by_name(artifact: dict) -> dict:
    return {r["name"]: r for r in artifact.get("rows", [])
            if isinstance(r, dict) and "name" in r}


def diff_artifact(cur: dict, base: dict, tolerance: float, min_us: float):
    """-> (regressions, improvements, notes) as printable strings."""
    regressions, improvements, notes = [], [], []
    cur_rows, base_rows = _rows_by_name(cur), _rows_by_name(base)
    for name, row in sorted(cur_rows.items()):
        prev = base_rows.get(name)
        if prev is None:
            notes.append(f"  new row: {name}")
            continue
        c_us, b_us = float(row.get("us_per_call", 0)), float(
            prev.get("us_per_call", 0))
        if c_us == 0 or b_us == 0:
            # pass/fail or ratio rows: a flag flip is the regression signal
            if prev.get("passed") is True and row.get("passed") is False:
                regressions.append(
                    f"  {name}: passed True -> False ({row})")
            continue
        rel = c_us / b_us - 1.0
        line = (f"  {name}: {b_us / 1e3:.1f}ms -> {c_us / 1e3:.1f}ms "
                f"({rel:+.0%})")
        if rel > tolerance:
            if max(c_us, b_us) < min_us:
                notes.append(line + "  [below noise floor, ignored]")
            else:
                regressions.append(line)
        elif rel < -tolerance and max(c_us, b_us) >= min_us:
            improvements.append(line)
    return regressions, improvements, notes


def gate_failures(cur: dict) -> list:
    """Baseline-independent failures: rows with a self-contained gate."""
    failures = []
    for name, row in sorted(_rows_by_name(cur).items()):
        if "gate_max_pct" not in row:
            continue
        if row.get("passed") is False:
            failures.append(
                f"  {name}: GATE FAILED — "
                f"{row.get('overhead_pct', '?')}% > "
                f"{row['gate_max_pct']}% budget ({row})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", default=list(DEFAULT_FILES))
    ap.add_argument("--baseline-dir", default=None,
                    help="directory holding the previous CI run's uploaded "
                         "bench artifact (same runner class; preferred "
                         "over --baseline-ref when the file is found)")
    ap.add_argument("--baseline-ref", default="HEAD~1",
                    help="git ref holding the previous artifact "
                         "(cross-hardware fallback)")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="relative slowdown that fails the job (0.2 = +20%%)")
    ap.add_argument("--min-us", type=float, default=50_000,
                    help="noise floor: rows faster than this never fail")
    ap.add_argument("--warn-only", action="store_true",
                    help="report baseline regressions but exit 0 "
                         "(absolute gate_max_pct rows still fail: they "
                         "compare within one process, so runner noise "
                         "does not excuse them)")
    args = ap.parse_args(argv)
    files = args.files or list(DEFAULT_FILES)

    failed = gate_failed = False
    for path in files:
        cur = _load_current(path)
        if cur is None:
            print(f"# {path}: no current artifact (bench not run?) — skipped")
            continue
        gates = gate_failures(cur)
        if gates:
            print(f"# {path} absolute gates:")
            for line in gates:
                print(line)
            failed = gate_failed = True
        base = None
        provenance = args.baseline_ref
        hit = _load_baseline_dir(args.baseline_dir, path)
        if hit is not None:
            base, provenance = hit[0], f"{hit[1]} (previous CI run)"
        if base is None:
            if args.baseline_dir:
                print(f"# {path}: not in --baseline-dir "
                      f"{args.baseline_dir} — falling back to git ref")
            base = _load_baseline(args.baseline_ref, path)
        if base is None:
            print(f"# {path}: no baseline at {args.baseline_ref} — skipped "
                  "(first run or shallow clone)")
            continue
        scale = ("bench_universities", "n_base_triples")
        if any(cur.get(k) != base.get(k) for k in scale):
            print(f"# {path}: benchmark scale changed "
                  f"({ {k: (base.get(k), cur.get(k)) for k in scale} }) — "
                  "timings not comparable, skipped")
            continue
        reg, imp, notes = diff_artifact(cur, base, args.tolerance,
                                        args.min_us)
        print(f"# {path} vs {provenance} "
              f"(tolerance +{args.tolerance:.0%}, floor {args.min_us / 1e3:.0f}ms)")
        for line in notes:
            print(line)
        if imp:
            print(" improvements:")
            for line in imp:
                print(line)
        if reg:
            print(" REGRESSIONS:")
            for line in reg:
                print(line)
            failed = True
        if not reg and not imp:
            print("  no significant changes")

    if gate_failed:
        print("bench_diff: FAILED (absolute gate violated)")
        return 1
    if failed and not args.warn_only:
        print("bench_diff: FAILED (see REGRESSIONS above)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
