#!/usr/bin/env python
"""Operator-facing fleet report from an exported metrics snapshot.

Consumes one per-process mergeable snapshot
(``repro.obs.export.export_mergeable_metrics``) or an aggregated fleet
snapshot (``repro.obs.aggregate``) and renders three sections:

  * **Device memory** — per-shard HBM bytes by component (base / delta /
    alive / tbox / snapshot / stack slabs) from the resource-ledger
    gauges, with live triples and bytes-per-triple per shard plus the
    fleet totals — the number ROADMAP item 4's compression work is
    gated on.
  * **SLO status** — per-SLO state and fast/slow error-budget burn rates
    from the burn-rate monitor's gauges, plus the runtime's current
    admission bound when the control loop has adjusted it.
  * **Slow signatures** — top-N plan signatures by total compile + exec
    seconds (``query/compile_seconds{sig=}`` + ``query/exec_seconds``
    histogram sums), with dispatch counts and plan-cache hit ratios —
    where to aim prewarm() and capacity tuning.

Usage:
    PYTHONPATH=src python scripts/fleet_report.py fleet.json [--top 10]

Exit codes: 0 report rendered, 1 unreadable/invalid snapshot.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.export import validate_metrics_snapshot

_STATE_NAMES = {0: "ok", 1: "WARN", 2: "PAGE"}


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:,.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:,.1f} GiB"


def _gauges(snap: dict, name: str) -> list:
    return [e for e in snap["gauges"] if e["name"] == name]


def memory_section(snap: dict) -> list:
    """Per-shard HBM table from the resource-ledger gauges."""
    lines = ["== Device memory (resource ledger) =="]
    shards: dict = {}
    for e in _gauges(snap, "hbm_bytes"):
        lab = e["labels"]
        key = (lab.get("process", "-"), lab.get("shard", "?"))
        shards.setdefault(key, {})[lab.get("component", "?")] = e["value"]
    triples = {(e["labels"].get("process", "-"),
                e["labels"].get("shard", "?")): e["value"]
               for e in _gauges(snap, "store/live_triples")}
    if not shards:
        lines.append("  (no ledger gauges in snapshot — nothing sampled)")
        return lines
    components = sorted({c for comps in shards.values() for c in comps})
    hdr = (["proc", "shard"] + components
           + ["total", "triples", "bytes/triple"])
    rows = []
    for key in sorted(shards):
        comps = shards[key]
        total = sum(comps.values())
        n = triples.get(key, 0)
        rows.append([key[0], key[1]]
                    + [_fmt_bytes(comps.get(c, 0)) for c in components]
                    + [_fmt_bytes(total), f"{int(n):,}",
                       f"{total / n:.1f}" if n else "-"])
    widths = [max(len(str(r[i])) for r in [hdr] + rows)
              for i in range(len(hdr))]
    for r in [hdr] + rows:
        lines.append("  " + "  ".join(
            str(v).rjust(w) for v, w in zip(r, widths)))
    total_b = sum(sum(c.values()) for c in shards.values())
    total_t = sum(triples.values())
    lines.append(f"  fleet total: {_fmt_bytes(total_b)} over "
                 f"{int(total_t):,} live triples"
                 + (f" = {total_b / total_t:.1f} bytes/triple"
                    if total_t else ""))
    return lines


def slo_section(snap: dict) -> list:
    """Per-SLO burn-rate status from the monitor's gauges."""
    lines = ["== SLO status (error-budget burn rates) =="]
    states = {}
    for e in _gauges(snap, "slo/state"):
        key = (e["labels"].get("process", "-"), e["labels"].get("slo", "?"))
        states[key] = int(e["value"])
    burns: dict = {}
    for e in _gauges(snap, "slo/burn_rate"):
        lab = e["labels"]
        key = (lab.get("process", "-"), lab.get("slo", "?"))
        burns.setdefault(key, {})[lab.get("window", "?")] = e["value"]
    if not states:
        lines.append("  (no SLO gauges in snapshot — monitor not enabled)")
        return lines
    for key in sorted(states):
        b = burns.get(key, {})
        state = _STATE_NAMES.get(states[key], str(states[key]))
        proc = f"proc={key[0]} " if key[0] != "-" else ""
        lines.append(
            f"  {proc}{key[1]:<16} {state:<5} "
            f"burn fast={b.get('fast', 0.0):7.2f}x "
            f"slow={b.get('slow', 0.0):7.2f}x of budget")
    for e in _gauges(snap, "serving/admission_bound"):
        proc = e["labels"].get("process")
        tag = f" (proc={proc})" if proc else ""
        lines.append(f"  admission bound{tag}: {int(e['value'])}")
    return lines


def slow_signatures(snap: dict, top: int) -> list:
    """Top-N plan signatures by compile+exec cost."""
    lines = [f"== Top {top} slow signatures (compile + exec seconds) =="]
    cost: dict = {}
    for e in snap["histograms"]:
        sig = e["labels"].get("sig")
        if sig is None or e["name"] not in ("query/compile_seconds",
                                            "query/exec_seconds"):
            continue
        rec = cost.setdefault(sig, {"compile_s": 0.0, "exec_s": 0.0,
                                    "dispatches": 0, "compiles": 0})
        if e["name"] == "query/compile_seconds":
            rec["compile_s"] += e["sum"]
            rec["compiles"] += e["count"]
        else:
            rec["exec_s"] += e["sum"]
            rec["dispatches"] += e["count"]
    hits: dict = {}
    misses: dict = {}
    for e in snap["counters"]:
        if e["name"] != "query/plan_cache":
            continue
        sig = e["labels"].get("sig")
        if sig is None:
            continue
        bucket = (hits if e["labels"].get("event", "").startswith("hit")
                  else misses)
        bucket[sig] = bucket.get(sig, 0) + e["value"]
    if not cost:
        lines.append("  (no per-signature cost histograms in snapshot)")
        return lines
    ranked = sorted(cost.items(),
                    key=lambda kv: kv[1]["compile_s"] + kv[1]["exec_s"],
                    reverse=True)[:top]
    lines.append(f"  {'signature':<16} {'total_s':>9} {'compile_s':>10} "
                 f"{'exec_s':>8} {'dispatches':>10} {'hit_ratio':>9}")
    for sig, rec in ranked:
        h, m = hits.get(sig, 0), misses.get(sig, 0)
        ratio = f"{h / (h + m):.2f}" if (h + m) else "-"
        lines.append(
            f"  {sig:<16} {rec['compile_s'] + rec['exec_s']:>9.3f} "
            f"{rec['compile_s']:>10.3f} {rec['exec_s']:>8.3f} "
            f"{rec['dispatches']:>10} {ratio:>9}")
    return lines


def render(snap: dict, top: int = 10) -> str:
    header = [f"fleet report — schema {snap['schema']}"]
    if "processes" in snap:
        header.append(f"processes: {', '.join(snap['processes'])}")
    else:
        header.append(f"process: {snap['process']}")
    sections = (memory_section(snap), slo_section(snap),
                slow_signatures(snap, top))
    return "\n".join(header + [""]
                     + [line for sec in sections for line in sec + [""]])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("snapshot", help="mergeable or fleet snapshot JSON")
    ap.add_argument("--top", type=int, default=10,
                    help="slow-signature rows to show")
    args = ap.parse_args(argv)
    try:
        with open(args.snapshot) as f:
            snap = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{args.snapshot}: unreadable ({e})", file=sys.stderr)
        return 1
    errors = validate_metrics_snapshot(snap)
    if errors:
        for err in errors:
            print(f"{args.snapshot}: {err}", file=sys.stderr)
        return 1
    print(render(snap, top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
