"""Multi-process jax.distributed smoke: 2 processes x 4 forced devices.

CI launches this script twice (process 0 is the coordinator) with
``--xla_force_host_platform_device_count=4`` per process, so the global
runtime sees 8 devices across 2 processes — the smallest shape that
exercises the multi-host runtime the repartition join targets.

Each process:
  1. initializes ``jax.distributed`` and checks the global/local device
     topology,
  2. runs a cross-process collective (psum over the global mesh) to prove
     the exchange fabric the all-to-all repartition rides on is live,
  3. builds a ShardedKB over its LOCAL devices and runs the repartition
     join + sharded-encode ingest parity against the single-device engine
     (per-process store placement is still local-device scoped; the global
     mesh migration is tracked in ROADMAP item 2).

Usage (CI runs both, backgrounding process 1):
    python scripts/distributed_smoke.py --process-id 0 --num-processes 2
    python scripts/distributed_smoke.py --process-id 1 --num-processes 2
"""
from __future__ import annotations

import argparse
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", default="127.0.0.1:9955")
    ap.add_argument("--num-processes", type=int, default=2)
    ap.add_argument("--process-id", type=int, required=True)
    ap.add_argument("--local-devices", type=int, default=4)
    ap.add_argument("--metrics-dir", default="",
                    help="export per-process mergeable metrics snapshots "
                         "here; process 0 aggregates them into fleet.json")
    args = ap.parse_args()

    import jax

    jax.distributed.initialize(
        coordinator_address=args.coordinator,
        num_processes=args.num_processes,
        process_id=args.process_id,
    )
    import jax.numpy as jnp
    import numpy as np

    nglobal = args.num_processes * args.local_devices
    assert jax.device_count() == nglobal, (jax.device_count(), nglobal)
    assert jax.local_device_count() == args.local_devices

    # 1. cross-process collective over the GLOBAL mesh: the exchange fabric.
    # jax 0.4.x's CPU backend has no multiprocess collectives (0.5+ routes
    # them through gloo) — degrade to a topology-only check there so the
    # smoke still validates the runtime wiring on old pins.
    try:
        out = jax.pmap(lambda x: jax.lax.psum(x, "i"), axis_name="i")(
            jnp.ones((jax.local_device_count(),), jnp.int32))
        assert int(np.asarray(out)[0]) == nglobal, np.asarray(out)
        print(f"[proc {args.process_id}] collective OK: psum={int(out[0])} "
              f"over {nglobal} devices / {args.num_processes} processes",
              flush=True)
    except Exception as e:  # pragma: no cover - backend-dependent
        if "aren't implemented" not in str(e):
            raise
        print(f"[proc {args.process_id}] collective SKIPPED "
              f"(CPU backend lacks multiprocess collectives): {e}",
              flush=True)

    # 2. repartition-join parity over this process's local devices
    from repro.core.engine import KnowledgeBase, PAPER_QUERIES
    from repro.core.shard import ShardedKB
    from repro.obs.metrics import REGISTRY
    from repro.rdf.generator import generate_lubm

    raw = generate_lubm(1, seed=7)
    K = KnowledgeBase.build(raw)
    S = ShardedKB.build(raw, n_shards=args.local_devices)
    S.track_ledger()  # per-shard hbm_bytes gauges ride the metrics export
    eng = S.engine("litemat")
    assert eng._shard_map_on() and eng._repartition_on()
    c = REGISTRY.counter("device/transfer_bytes", src="combine_upload")
    before = c.value
    want, _ = K.query(PAPER_QUERIES["Q4"], mode="litemat")
    got, _ = eng.run(PAPER_QUERIES["Q4"])
    assert np.array_equal(np.asarray(got), want)
    assert eng.cache_stats["repartition_runs"] >= 1, eng.cache_stats
    assert c.value == before, "device combine leaked a host re-upload"
    print(f"[proc {args.process_id}] repartition join OK: "
          f"{want.shape[0]} rows, zero host uploads", flush=True)

    # 3. sharded-encode ingest on local devices stays fp-space identical
    from repro.core.tbox import build_tbox
    from repro.utils import pair64

    n = raw.s.shape[0]
    half = n // 2
    parts = [(raw.s[:half], raw.p[:half], raw.o[:half]),
             (raw.s[half:], raw.p[half:], raw.o[half:])]
    SI = ShardedKB.ingest(iter(parts), onto=raw.onto,
                          n_shards=args.local_devices)
    assert SI.use_sharded_encode and SI._sharded_encode_on()

    def answers_fp(kb, pats):
        rows, _ = kb.query(pats, mode="litemat")
        if rows.size == 0:
            return set()
        ids = jnp.asarray(np.asarray(rows).reshape(-1).astype(np.int32))
        hi, lo, hit = kb.kb.table.extract_fp(ids)
        fps = pair64.combine_np(np.asarray(hi), np.asarray(lo))
        fps = np.where(np.asarray(hit), fps, np.asarray(rows).reshape(-1))
        return {tuple(r) for r in fps.reshape(rows.shape).tolist()}

    ctrl = ShardedKB.empty(build_tbox(raw.onto), n_shards=args.local_devices)
    for p in parts:
        ctrl.insert(p, auto_compact=False)
    a = answers_fp(SI, PAPER_QUERIES["Q1"])
    assert a == answers_fp(ctrl, PAPER_QUERIES["Q1"]) and len(a) > 0
    print(f"[proc {args.process_id}] sharded encode OK: {len(a)} answers",
          flush=True)

    # 4. cross-process telemetry: every process exports a mergeable
    # snapshot; process 0 waits for its peers' files and aggregates them
    # into ONE schema-validated fleet snapshot (the artifact CI uploads).
    if args.metrics_dir:
        _export_and_aggregate(args)

    print(f"[proc {args.process_id}] DISTRIBUTED SMOKE PASSED", flush=True)
    return 0


def _export_and_aggregate(args) -> None:
    import json
    import os
    import time

    from repro.obs.aggregate import aggregate, check_compatible
    from repro.obs.export import (export_mergeable_metrics,
                                  validate_metrics_snapshot)
    from repro.obs.ledger import LEDGER
    from repro.obs.metrics import REGISTRY

    os.makedirs(args.metrics_dir, exist_ok=True)
    LEDGER.sample()  # land hbm_bytes/bytes_per_triple gauges pre-export
    mine = os.path.join(args.metrics_dir,
                        f"metrics-proc{args.process_id}.json")
    snap = export_mergeable_metrics(REGISTRY, mine,
                                    process=str(args.process_id))
    print(f"[proc {args.process_id}] exported {len(snap['counters'])} "
          f"counters / {len(snap['histograms'])} histograms -> {mine}",
          flush=True)
    if args.process_id != 0:
        return

    paths = [os.path.join(args.metrics_dir, f"metrics-proc{i}.json")
             for i in range(args.num_processes)]
    deadline = time.monotonic() + 60.0
    snaps = {}
    while len(snaps) < len(paths):
        for p in paths:
            if p in snaps or not os.path.exists(p):
                continue
            try:
                with open(p) as f:
                    snaps[p] = json.load(f)
            except json.JSONDecodeError:
                continue  # peer mid-write: retry next poll
        if len(snaps) < len(paths):
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"peer snapshots missing: "
                    f"{[p for p in paths if p not in snaps]}")
            time.sleep(0.2)
    ordered = [snaps[p] for p in paths]
    for p, s in zip(paths, ordered):
        errors = validate_metrics_snapshot(s)
        assert not errors, (p, errors)
    check_compatible(ordered)
    fleet = aggregate(ordered)
    errors = validate_metrics_snapshot(fleet)
    assert not errors, errors
    # counters must SUM across processes: every process ran the same
    # repartition check, so the fleet's run counter is n_processes times
    # any single process's
    key = "shard/combine_runs"
    mine_runs = sum(e["value"] for e in ordered[0]["counters"]
                    if e["name"] == key)
    fleet_runs = sum(e["value"] for e in fleet["counters"]
                     if e["name"] == key)
    per_proc = [sum(e["value"] for e in s["counters"] if e["name"] == key)
                for s in ordered]
    assert fleet_runs == sum(per_proc) and mine_runs > 0, (
        fleet_runs, per_proc)
    # histogram counts must merge bucket-wise (sum of member counts)
    fh = {(e["name"], tuple(sorted(e["labels"].items()))): e
          for e in fleet["histograms"]}
    for s in ordered:
        for e in s["histograms"]:
            k = (e["name"], tuple(sorted(e["labels"].items())))
            assert k in fh, k
    out = os.path.join(args.metrics_dir, "fleet.json")
    with open(out, "w") as f:
        json.dump(fleet, f, indent=1, sort_keys=True)
    print(f"[proc 0] fleet aggregation OK: {len(ordered)} processes -> "
          f"{out} ({fleet_runs} combine runs fleet-wide)", flush=True)


if __name__ == "__main__":
    sys.exit(main())
