#!/usr/bin/env bash
# Tier-1 verification on CPU. Pallas kernels run in interpret=True mode
# (selected automatically off-TPU), so kernel code is exercised end-to-end.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
