#!/usr/bin/env python
"""Render a trace export as per-request waterfalls + a slow-span table.

Reads the JSON file ``repro.obs.export.export_traces`` writes (the
serving bench's ``REPRO_TRACE_EXPORT`` hook) and prints:

  * a text waterfall per trace — spans indented by parent, each with a
    bar positioned in the request's [t0, t1] window, duration, and the
    attrs that explain the shape (pin path, stale degradation, retry
    attempt, shard dispatch path),
  * a top-N table of the slowest spans across every trace, the place to
    look first when a p99 regresses.

Usage:
    PYTHONPATH=src python scripts/trace_report.py traces.json
    PYTHONPATH=src python scripts/trace_report.py traces.json \
        --top 20 --max-traces 5 --slowest

``--slowest`` orders the waterfall section by root-span duration
(descending) instead of submission order, so the traces shown are the
requests worth reading.
"""
from __future__ import annotations

import argparse
import json
import sys

BAR_WIDTH = 40


def _fmt_attrs(span: dict) -> str:
    attrs = dict(span.get("attrs", {}))
    parts = [f"{k}={v}" for k, v in attrs.items()]
    parts += [f"!{ev['name']}" for ev in span.get("events", [])]
    return (" [" + " ".join(parts) + "]") if parts else ""


def _children(spans: list) -> dict:
    by_parent: dict = {}
    for s in spans:
        by_parent.setdefault(s["parent_id"], []).append(s)
    for kids in by_parent.values():
        kids.sort(key=lambda s: (s["t0"], s["span_id"]))
    return by_parent


def waterfall(trace: dict, out=sys.stdout) -> None:
    spans = trace["spans"]
    roots = [s for s in spans if s["parent_id"] == -1]
    if not roots:
        return
    root = roots[0]
    t0, t1 = root["t0"], max(s["t1"] for s in spans)
    window = max(t1 - t0, 1e-9)
    by_parent = _children(spans)
    out.write(f"{trace['trace_id']}  "
              f"({(root['t1'] - root['t0']) * 1e3:.2f} ms)"
              f"{_fmt_attrs(root)}\n")

    def emit(span: dict, depth: int) -> None:
        lo = int((span["t0"] - t0) / window * BAR_WIDTH)
        hi = max(int((span["t1"] - t0) / window * BAR_WIDTH), lo + 1)
        bar = " " * lo + "#" * (hi - lo) + " " * (BAR_WIDTH - hi)
        dur_ms = (span["t1"] - span["t0"]) * 1e3
        label = "  " * depth + span["name"]
        out.write(f"  |{bar}| {dur_ms:9.3f} ms  "
                  f"{label}{_fmt_attrs(span)}\n")
        for kid in by_parent.get(span["span_id"], []):
            emit(kid, depth + 1)

    emit(root, 0)
    out.write("\n")


def slow_spans(traces: list, top: int) -> list:
    """[(duration_s, trace_id, span)] of the ``top`` slowest spans."""
    rows = []
    for trace in traces:
        for s in trace["spans"]:
            rows.append((s["t1"] - s["t0"], trace["trace_id"], s))
    rows.sort(key=lambda r: -r[0])
    return rows[:top]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("file")
    ap.add_argument("--top", type=int, default=10,
                    help="rows in the slowest-spans table")
    ap.add_argument("--max-traces", type=int, default=10,
                    help="waterfalls to print (0 = none)")
    ap.add_argument("--slowest", action="store_true",
                    help="order waterfalls by root duration, not arrival")
    args = ap.parse_args(argv)

    with open(args.file) as f:
        doc = json.load(f)
    traces = [t for t in doc.get("traces", []) if t.get("spans")]
    if not traces:
        print(f"{args.file}: no traces")
        return 1

    shown = traces
    if args.slowest:
        shown = sorted(traces, key=lambda t: t["spans"][0]["t0"]
                       - t["spans"][0]["t1"])
    for trace in shown[:args.max_traces]:
        waterfall(trace)

    print(f"top {args.top} slowest spans "
          f"({len(traces)} traces, {doc.get('dropped', 0)} dropped):")
    for dur, tid, s in slow_spans(traces, args.top):
        print(f"  {dur * 1e3:9.3f} ms  {s['name']:<14} {tid}"
              f"{_fmt_attrs(s)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
