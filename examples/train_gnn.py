"""Train GAT on a synthetic Cora with LiteMat-encoded semantic edges.

Demonstrates the GNN-family tie-in (DESIGN.md §4): edges carry LiteMat
property ids, and the training graph is restricted to a *semantic
neighborhood* — all edges whose type is subsumed by a query property —
with one interval compare instead of a set-membership filter.

    PYTHONPATH=src python examples/train_gnn.py [--steps 200]
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.tbox import Ontology, build_tbox
from repro.data.graphs import make_cora_like
from repro.launch.cells import make_gnn_train_step
from repro.models.gnn import gat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--nodes", type=int, default=1000)
    args = ap.parse_args()

    # a tiny edge-type ontology: cites <= relatedTo, refutes <= relatedTo
    onto = Ontology(
        concepts=["Paper"], properties=["relatedTo", "cites", "refutes", "sameVenue"],
        subprop=[("cites", "relatedTo"), ("refutes", "relatedTo")],
    )
    tbox = build_tbox(onto)
    penc = tbox.properties

    g = make_cora_like(n_nodes=args.nodes, n_edges=args.nodes * 5, d_feat=64, seed=0)
    rng = np.random.default_rng(0)
    names = ["cites", "refutes", "sameVenue"]
    etype = np.array([penc.id_of(names[i]) for i in rng.integers(0, 3, len(g["edges"]))],
                     dtype=np.int32)

    # semantic neighborhood: one interval compare selects cites+refutes edges
    (lo, hi), _ = penc.interval_of("relatedTo")
    keep = (etype >= lo) & (etype < hi)
    print(f"semantic filter relatedTo: kept {keep.sum()}/{len(etype)} edges "
          f"(interval [{lo},{hi}) — no per-subproperty scan)")
    g["edges"] = g["edges"][keep]

    gj = {k: jnp.asarray(v) for k, v in g.items()}
    cfg = gat.GATConfig(d_in=64, d_hidden=8, n_heads=8)
    params = gat.init_params(jax.random.key(0), cfg)
    step = jax.jit(make_gnn_train_step("gat", cfg, "cls", lr=0.5))

    for i in range(args.steps):
        params, loss = step(params, gj)
        if i % 25 == 0 or i == args.steps - 1:
            logits = gat.forward(params, gj, cfg)
            acc = float((jnp.argmax(logits, -1) == gj["labels"]).mean())
            print(f"step {i:>4}: loss={float(loss):.4f} acc={acc:.3f}")


if __name__ == "__main__":
    main()
