"""End-to-end driver (the paper's workload at serving scale):

generate a multi-university LUBM-style KB (~0.5M triples by default) ->
OBE-encode -> lite-materialize -> serve batched parameterized SPARQL-style
queries through the vmapped LiteMat plans, with a completeness audit
against the full-materialization and rewriting baselines.

    PYTHONPATH=src python examples/serve_queries.py [--universities 4]
"""
import argparse
import time

import numpy as np

from repro.core.engine import PAPER_QUERIES, KnowledgeBase
from repro.rdf.generator import generate_lubm
from repro.serving.engine import QueryServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--universities", type=int, default=4)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch", type=int, default=256)
    args = ap.parse_args()

    t0 = time.time()
    raw = generate_lubm(args.universities, seed=0)
    print(f"generated {raw.n_triples:,} triples in {time.time()-t0:.1f}s")

    t0 = time.time()
    K = KnowledgeBase.build(raw)
    print(f"encoded + materialized in {time.time()-t0:.1f}s; sizes={K.sizes()}")

    # completeness audit (the paper's own validation)
    for qn, pats in PAPER_QUERIES.items():
        res = {m: K.answers(pats, mode=m) for m in ("litemat", "full", "rewrite")}
        assert res["litemat"] == res["full"] == res["rewrite"], qn
        print(f"  {qn}: {len(res['litemat']):,} answers — complete in all 3 modes")

    srv = QueryServer(K)
    classes = ["Professor", "Student", "Faculty", "Person", "Course",
               "Publication", "Organization", "Department"]
    rng = np.random.default_rng(0)
    srv.class_members(classes)  # warm/compile

    t0 = time.time()
    total = 0
    for _ in range(args.batches):
        names = [classes[i] for i in rng.integers(0, len(classes), args.batch)]
        counts, members = srv.class_members(names)
        total += len(names)
    wall = time.time() - t0
    print(f"served {total:,} class-member queries in {wall:.2f}s "
          f"-> {total/wall:,.0f} q/s (batch={args.batch})")


if __name__ == "__main__":
    main()
