"""End-to-end driver (the paper's workload at serving scale):

generate a multi-university LUBM-style KB (~0.5M triples by default) ->
OBE-encode -> lite-materialize -> serve batched parameterized SPARQL-style
queries through the vmapped LiteMat plans, with a completeness audit
against the full-materialization and rewriting baselines — then keep
serving while the store takes live inserts: the delta overlay absorbs the
new triples without a rebuild, and the server notices the version bump by
itself (no invalidate() call anywhere in this file).

    PYTHONPATH=src python examples/serve_queries.py [--universities 4]
"""
import argparse
import time

import numpy as np

from repro.core.engine import PAPER_QUERIES, KnowledgeBase
from repro.rdf.generator import generate_lubm
from repro.serving.engine import QueryServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--universities", type=int, default=4)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch", type=int, default=256)
    args = ap.parse_args()

    t0 = time.time()
    raw = generate_lubm(args.universities, seed=0)
    print(f"generated {raw.n_triples:,} triples in {time.time()-t0:.1f}s")

    t0 = time.time()
    K = KnowledgeBase.build(raw)
    print(f"encoded + materialized in {time.time()-t0:.1f}s; sizes={K.sizes()}")

    # pre-trace the Q1-Q4 executables so the first live query pays no
    # compile (the plan cache is otherwise populated lazily per bucket)
    t0 = time.time()
    n_plans = K.prewarm()
    print(f"prewarmed {n_plans} query plans in {time.time()-t0:.1f}s")

    # completeness audit (the paper's own validation)
    for qn, pats in PAPER_QUERIES.items():
        res = {m: K.answers(pats, mode=m) for m in ("litemat", "full", "rewrite")}
        assert res["litemat"] == res["full"] == res["rewrite"], qn
        print(f"  {qn}: {len(res['litemat']):,} answers — complete in all 3 modes")

    srv = QueryServer(K)
    classes = ["Professor", "Student", "Faculty", "Person", "Course",
               "Publication", "Organization", "Department"]
    rng = np.random.default_rng(0)
    srv.class_members(classes)  # warm/compile

    t0 = time.time()
    total = 0
    for _ in range(args.batches):
        names = [classes[i] for i in rng.integers(0, len(classes), args.batch)]
        counts, members = srv.class_members(names)
        total += len(names)
    wall = time.time() - t0
    print(f"served {total:,} class-member queries in {wall:.2f}s "
          f"-> {total/wall:,.0f} q/s (batch={args.batch})")

    # ---- live updates: insert while serving -------------------------------
    before, _ = srv.class_members(["Student"])
    # a brand-new university: every instance term is new to the dictionary
    delta = generate_lubm(1, seed=1234, univ_offset=args.universities)
    t0 = time.time()
    st = K.insert(delta, auto_compact=False)
    print(f"inserted {st['n_inserted']:,} triples "
          f"({st['n_new_terms']:,} new terms) in {time.time()-t0:.2f}s "
          f"-> delta ratio {st['delta_ratio']:.3f}, version {K.version}")
    after, _ = srv.class_members(["Student"])  # picks up the delta by itself
    print(f"Student members {int(before[0]):,} -> {int(after[0]):,} "
          "(server re-synced automatically)")
    assert int(after[0]) > int(before[0])

    # compaction folds the overlay back into the base stores (sorted merge)
    t0 = time.time()
    st = K.compact()
    t_compact = time.time() - t0
    stable, _ = srv.class_members(["Student"])
    print(f"compacted to sizes={K.sizes()} in {t_compact:.2f}s; "
          f"answers stable: {int(stable[0]) == int(after[0])}")


if __name__ == "__main__":
    main()
