"""Quickstart: the whole LiteMat pipeline on the paper's Example 1.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.engine import KnowledgeBase
from repro.core.query import Pattern
from repro.rdf.parser import parse_ntriples

# The paper's Example 1: Professor <= FacultyMember, domain(teaches) =
# FacultyMember; bernd is an explicit Professor, hubert only teaches.
NT = """
<http://ex/Professor> <http://www.w3.org/2000/01/rdf-schema#subClassOf> <http://ex/FacultyMember> .
<http://ex/teaches> <http://www.w3.org/2000/01/rdf-schema#domain> <http://ex/FacultyMember> .
<http://ex/bernd> <http://www.w3.org/1999/02/22-rdf-syntax-ns#type> <http://ex/Professor> .
<http://ex/hubert> <http://ex/teaches> <http://ex/course1> .
"""


def main():
    ds, onto = parse_ntriples(NT)
    print(f"parsed {ds.n_triples} ABox triples; ontology: {onto.stats()}")

    K = KnowledgeBase.build(ds)
    print("store sizes:", K.sizes())
    print("concept encoding:")
    enc = K.kb.tbox.concepts
    for name in enc.tax.names:
        if name.startswith("__"):
            continue
        (lo, hi), _ = enc.interval_of(name)
        print(f"  {name:<28} id={lo:>4} interval=[{lo}, {hi})")

    # 'SELECT ?x WHERE { ?x rdf:type FacultyMember }' — the naive store has
    # NO FacultyMember triples; LiteMat answers via ONE interval compare.
    q = [Pattern("?x", "rdf:type", "<http://ex/FacultyMember>")]
    for mode in ("litemat", "full", "rewrite"):
        rows = sorted(K.answers(q, mode=mode))
        names = K.kb.extract([r[0] for r in rows])
        print(f"{mode:>8}: {names}")
    assert len(K.answers(q)) == 2, "bernd (explicit) + hubert (domain-derived)"
    print("OK — both bernd and hubert are FacultyMembers under RDFS entailment")


if __name__ == "__main__":
    main()
