"""Train a reduced OLMo-style LM for a few hundred steps with the full
fault-tolerance substrate (checkpoints, deterministic resume).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Kill it mid-run (Ctrl-C / SIGTERM) and re-run: it resumes from the last
checkpoint bit-exactly.
"""
import argparse

import jax

from repro.configs.registry import get_arch
from repro.data.tokens import TokenStream
from repro.distributed.checkpoint import CheckpointManager
from repro.models import lm as lm_lib
from repro.train.loop import TrainLoop
from repro.train.optimizer import AdamWConfig, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm_example")
    args = ap.parse_args()

    cfg = get_arch("olmo-1b").reduced_config()
    params = lm_lib.init_params(jax.random.key(0), cfg)
    opt = init_opt_state(params)
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {cfg.name} ({n:,} params)")

    loop = TrainLoop(
        step_fn=jax.jit(lm_lib.make_train_step(cfg, AdamWConfig(lr=3e-3))),
        batch_at=TokenStream(cfg.vocab, batch=8, seq_len=128, seed=1).batch_at,
        ckpt=CheckpointManager(args.ckpt_dir),
        ckpt_every=100,
        log_every=25,
    )
    loop.install_signal_handlers()
    _, _, last, hist = loop.run(params, opt, args.steps)
    print(f"finished at step {last}: loss {hist[0]:.3f} -> {hist[-1]:.3f}")


if __name__ == "__main__":
    main()
